"""ResilienceManager — the front end's single handle on the fault plan,
retry policy, breaker board and degraded tier.

Constructed by :class:`ServiceFrontend` from the resilience knobs on
``ServiceConfig``; every method has a zero-overhead fast path when the
corresponding knob is off, so a service configured without resilience
runs the exact pre-existing code path.
"""
from __future__ import annotations

import random
import time
from typing import Callable, Optional

from repro.resilience.breaker import BreakerBoard
from repro.resilience.degrade import DegradedResult, lpa_result, stale_result
from repro.resilience.faults import FaultPlan
from repro.resilience.policy import RetryPolicy, run_with_policy


class ResilienceManager:
    def __init__(self, config, *, telemetry=None, metrics=None,
                 clock: Callable[[], float] = time.monotonic):
        self.plan: Optional[FaultPlan] = config.fault_plan
        self.retry: Optional[RetryPolicy] = config.retry
        self.telemetry = telemetry
        self.metrics = metrics
        self.clock = clock
        self.board = (BreakerBoard(config.breaker, clock=clock,
                                   telemetry=telemetry)
                      if config.breaker is not None else None)
        self.degrade_enabled = bool(config.degrade_enabled)
        self.degrade_modes = tuple(config.degrade_modes)
        # the service's DetectOptions: the degraded lpa mode runs the
        # portfolio's fast tier under the SAME backend knobs as a
        # requested fast-tier detect (one code path, bit-identical)
        self.detect_options = config.detect
        self._degrade_tenants = (None if config.degrade_tenants is None
                                 else frozenset(config.degrade_tenants))
        seed = getattr(self.plan, "seed", 0) if self.plan is not None else 0
        self._rng = random.Random(f"resilience-jitter:{seed}")
        self.n_retries = 0
        self.n_batch_splits = 0
        self.n_degraded = 0
        if self.plan is not None:
            self.plan.on_inject = self._note_inject

    # -- wiring ---------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return (self.plan is not None or self.retry is not None
                or self.board is not None or self.degrade_enabled)

    @property
    def _dispatch_active(self) -> bool:
        return (self.plan is not None or self.retry is not None
                or self.board is not None)

    def _counter(self, name, labels=None):
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.counter(name, 1, labels)

    def _note_inject(self, seam: str):
        self._counter("faults_injected", {"seam": seam})

    def _note_retry(self, kind: str, exc: BaseException):
        self.n_retries += 1
        if self.metrics is not None:
            self.metrics.n_retries += 1
        self._counter("resilience_retries",
                      {"kind": kind, "error": type(exc).__name__})

    def note_split(self):
        self.n_batch_splits += 1
        if self.metrics is not None:
            self.metrics.n_batch_splits += 1
        self._counter("resilience_batch_splits")

    # -- breaker --------------------------------------------------------
    def allow(self, bucket) -> bool:
        return True if self.board is None else self.board.allow(bucket)

    def breaker_state(self, bucket) -> Optional[str]:
        return None if self.board is None else self.board.state(bucket)

    # -- dispatch / commit seams ----------------------------------------
    def dispatch(self, kind: str, bucket, fn: Callable, *,
                 deadline: Optional[float] = None):
        """Engine dispatch under retry/watchdog, with the bucket breaker
        recording the outcome.  ``deadline`` is an absolute clock time
        bounding retries (min admission deadline of the batch)."""
        if not self._dispatch_active:
            return fn()
        t0 = self.clock()
        try:
            out = run_with_policy(
                fn, self.retry, clock=self.clock, deadline=deadline,
                rng=self._rng,
                on_retry=lambda a, e: self._note_retry(kind, e))
        except Exception:
            if self.board is not None:
                self.board.record_failure(bucket)
            raise
        if self.board is not None:
            self.board.record_success(bucket, self.clock() - t0)
        return out

    def commit(self, fn: Callable):
        """A store write under the ``store.commit`` fault seam and the
        retry policy (each attempt re-consults the seam, so count-limited
        faults succeed on retry)."""
        if self.plan is None and self.retry is None:
            return fn()

        def attempt():
            if self.plan is not None:
                self.plan.perturb("store.commit")
            return fn()

        return run_with_policy(
            attempt, self.retry, clock=self.clock, rng=self._rng,
            on_retry=lambda a, e: self._note_retry("commit", e))

    # -- degraded tier --------------------------------------------------
    def can_degrade(self, tenant: str) -> bool:
        if not self.degrade_enabled:
            return False
        return (self._degrade_tenants is None
                or tenant in self._degrade_tenants)

    def degraded(self, graph_id: str, graph, store, *, now: float,
                 tenant: str = "default") -> Optional[DegradedResult]:
        """Produce a degraded result for an opted-in tenant, trying the
        configured modes in order; ``None`` when nothing applies."""
        if not self.can_degrade(tenant):
            return None
        for mode in self.degrade_modes:
            if mode == "stale":
                entry = store.get(graph_id)
                if entry is None:
                    continue
                dr = stale_result(graph_id, entry, now=now)
            else:
                try:
                    dr = lpa_result(graph_id, graph,
                                    options=self.detect_options,
                                    telemetry=self.telemetry)
                except Exception:       # fast path must not fail the shed
                    continue
            self.n_degraded += 1
            if self.metrics is not None:
                self.metrics.n_degraded += 1
            self._counter("degraded_served", {"mode": mode})
            return dr
        return None
