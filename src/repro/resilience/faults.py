"""Deterministic, seedable fault injection at the service's real seams.

A :class:`FaultPlan` is installed through ``ServiceConfig(fault_plan=...)``
and consulted — never monkeypatched in — at the seams where a production
deployment actually fails:

======================  =================================================
seam                    where it fires
======================  =================================================
``engine.detect``       inside :meth:`BatchedLouvainEngine.detect_batch`,
                        before the jitted call (raise)
``engine.detect.hang``  same place, but sleeps ``hang_s`` instead of
                        raising — a stuck dispatch for the watchdog
``engine.update``       inside ``update_batch`` (raise)
``engine.update.hang``  same place, sleeping
``store.commit``        around every store write the front end makes
                        (fresh-detect ``put`` and warm ``commit_update``)
``checkpoint.io``       after an automatic snapshot lands: the written
                        ``arrays.npz`` is byte-truncated, simulating a
                        torn write the atomic rename could not prevent
``telemetry.sink``      a :class:`FaultySink` registered on the hub
                        raises from its event hooks
======================  =================================================

Each seam carries one or more :class:`FaultSpec` triggers: fire with
probability ``p`` per eligible call, at most ``count`` times, skipping the
first ``skip`` eligible calls, optionally only when the dispatched batch
contains one of ``graph_ids`` (the "poison graph" used by the split-retry
tests).  ``error="capacity"`` raises a :class:`TransientCapacityError`
(a retryable :class:`repro.core.dynamic.CapacityError`) instead of the
generic :class:`FaultError`.  All randomness comes from per-spec
``random.Random`` streams seeded from ``(seed, seam, index)``, so a plan
fires identically run-to-run regardless of thread interleaving across
seams.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

from repro.core.dynamic import CapacityError
from repro.telemetry.sinks import MetricSink


class FaultError(RuntimeError):
    """An injected failure (see the seam it fired at on ``.seam``)."""

    def __init__(self, seam: str, msg: Optional[str] = None):
        self.seam = seam
        super().__init__(msg or f"injected fault at seam {seam!r}")


class TransientCapacityError(CapacityError):
    """Injected *transient* capacity fault.

    Subclasses the real :class:`repro.core.dynamic.CapacityError` so
    callers see the production error type, but — unlike a genuine bucket
    overflow — a retry is expected to succeed (the retry policy treats it
    as retryable)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One trigger at one seam.

    p:         firing probability per eligible call (1.0 = always).
    count:     max firings over the plan's lifetime (None = unlimited).
    skip:      skip the first N eligible calls (lets a warm-up pass).
    hang_s:    > 0 sleeps instead of raising (a hung dispatch).
    error:     "fault" raises :class:`FaultError`; "capacity" raises
               :class:`TransientCapacityError`.
    graph_ids: when set, the spec is eligible only for calls whose
               ``ids`` intersect it (per-graph poison).
    """

    p: float = 1.0
    count: Optional[int] = None
    skip: int = 0
    hang_s: float = 0.0
    error: str = "fault"
    graph_ids: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if not (0.0 <= self.p <= 1.0):
            raise ValueError(f"p must be in [0, 1], got {self.p}")
        if self.count is not None and self.count < 0:
            raise ValueError(f"count must be >= 0, got {self.count}")
        if self.skip < 0:
            raise ValueError(f"skip must be >= 0, got {self.skip}")
        if self.hang_s < 0:
            raise ValueError(f"hang_s must be >= 0, got {self.hang_s}")
        if self.error not in ("fault", "capacity"):
            raise ValueError(
                f"error must be 'fault' or 'capacity', got {self.error!r}")
        if self.graph_ids is not None:
            object.__setattr__(self, "graph_ids", tuple(self.graph_ids))


SpecLike = Union[FaultSpec, Sequence[FaultSpec]]


class FaultPlan:
    """A seeded map of seam -> fault triggers, with injection counters.

    Thread-safe; decisions are deterministic per seam given the sequence
    of eligible calls at that seam (per-spec RNG streams).  ``injected``
    counts firings per seam; ``on_inject`` (set by the resilience
    manager) mirrors each firing to the telemetry hub.
    """

    def __init__(self, specs: Mapping[str, SpecLike], *, seed: int = 0):
        self.seed = int(seed)
        self._specs: Dict[str, Tuple[FaultSpec, ...]] = {}
        for seam, sp in dict(specs).items():
            if isinstance(sp, FaultSpec):
                sp = (sp,)
            self._specs[str(seam)] = tuple(sp)
        self._lock = threading.Lock()
        self.on_inject = None          # callable(seam) | None
        self.reset()

    @property
    def seams(self) -> Tuple[str, ...]:
        return tuple(self._specs)

    def spec(self, seam: str) -> Tuple[FaultSpec, ...]:
        return self._specs.get(seam, ())

    def reset(self):
        """Rewind every trigger and counter to the plan's initial state
        (a fresh, identical run)."""
        with self._lock:
            self._rngs = {
                (seam, i): random.Random(f"{self.seed}:{seam}:{i}")
                for seam, specs in self._specs.items()
                for i in range(len(specs))}
            self._eligible = {k: 0 for k in self._rngs}
            self._fired = {k: 0 for k in self._rngs}
            self.injected: Dict[str, int] = {s: 0 for s in self._specs}

    def injected_total(self) -> int:
        return sum(self.injected.values())

    def perturb(self, seam: str, ids: Optional[Sequence[str]] = None):
        """Consult ``seam``: sleep for a triggered hang spec, raise for a
        triggered error spec, otherwise return.  ``ids`` are the graph
        ids of the call (for ``graph_ids``-scoped specs; specs with a
        scope never fire when ids are unknown)."""
        specs = self._specs.get(seam)
        if not specs:
            return
        for i, spec in enumerate(specs):
            fire = False
            with self._lock:
                if spec.graph_ids is not None:
                    if ids is None or not set(spec.graph_ids).intersection(
                            ids):
                        continue
                key = (seam, i)
                if spec.count is not None and self._fired[key] >= spec.count:
                    continue
                self._eligible[key] += 1
                if self._eligible[key] <= spec.skip:
                    continue
                if spec.p < 1.0 and self._rngs[key].random() >= spec.p:
                    continue
                self._fired[key] += 1
                self.injected[seam] += 1
                fire = True
            if not fire:
                continue
            hook = self.on_inject
            if hook is not None:
                try:
                    hook(seam)
                except Exception:       # observability must not re-raise
                    pass
            if spec.hang_s > 0.0:
                time.sleep(spec.hang_s)
                continue
            if spec.error == "capacity":
                raise TransientCapacityError(
                    f"injected transient capacity fault at {seam!r}")
            raise FaultError(seam)

    def __repr__(self):
        return (f"FaultPlan(seed={self.seed}, seams={list(self._specs)}, "
                f"injected={self.injected_total()})")


class FaultySink(MetricSink):
    """A telemetry sink that raises per the plan's ``telemetry.sink``
    seam — exercises the hub's sink-error isolation (and the bounded
    ``sink_errors`` record) without monkeypatching.  Registered
    automatically by the front end when the installed plan names the
    seam.  Resilience/fault counters are ignored so the injection
    bookkeeping cannot recurse into itself."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def on_counter(self, name, value, labels=None):
        if name.startswith(("faults_", "resilience_")):
            return
        self.plan.perturb("telemetry.sink")

    def on_gauge(self, name, value, labels=None):
        self.plan.perturb("telemetry.sink")

    def on_span(self, span):
        self.plan.perturb("telemetry.sink")
