"""Fault-tolerance layer for the serving path (PR 9).

* :mod:`repro.resilience.faults`   — deterministic, seedable
  :class:`FaultPlan` injected at the service's real seams;
* :mod:`repro.resilience.policy`   — :class:`RetryPolicy` with
  exponential backoff + jitter, watchdog timeouts and wall-clock
  budgets honoring admission deadlines;
* :mod:`repro.resilience.breaker`  — per-bucket circuit breaker with
  half-open probing;
* :mod:`repro.resilience.degrade`  — degraded tier: stale last-committed
  partitions and the LPA fast path, both flagged as NOT carrying the
  zero-internally-disconnected guarantee;
* :mod:`repro.resilience.autockpt` — background automatic
  checkpointing, evicted-but-warm write-back and corrupt-tolerant
  startup recovery;
* :mod:`repro.resilience.manager`  — the front end's single handle on
  all of the above.

Installed via the resilience knobs on
:class:`repro.service.ServiceConfig`; see the README "Resilience &
failure handling" section.
"""
from repro.resilience.autockpt import AutoCheckpointer
from repro.resilience.breaker import (
    BreakerBoard,
    BreakerConfig,
    BreakerOpen,
    CircuitBreaker,
)
from repro.resilience.degrade import DegradedResult, lpa_result, stale_result
from repro.resilience.faults import (
    FaultError,
    FaultPlan,
    FaultSpec,
    FaultySink,
    TransientCapacityError,
)
from repro.resilience.manager import ResilienceManager
from repro.resilience.policy import (
    DeadlineExceeded,
    DispatchTimeout,
    RetryPolicy,
    call_with_timeout,
    run_with_policy,
)

__all__ = [
    "AutoCheckpointer",
    "BreakerBoard",
    "BreakerConfig",
    "BreakerOpen",
    "CircuitBreaker",
    "DeadlineExceeded",
    "DegradedResult",
    "DispatchTimeout",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "FaultySink",
    "ResilienceManager",
    "RetryPolicy",
    "TransientCapacityError",
    "call_with_timeout",
    "lpa_result",
    "run_with_policy",
    "stale_result",
]
