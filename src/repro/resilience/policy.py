"""Retry / timeout / backoff policies for dispatch and commit seams.

A :class:`RetryPolicy` is installed per service via
``ServiceConfig(retry=...)`` and wrapped around the two places the front
end does real work: engine dispatch (fresh detects and warm updates) and
store commits.  The policy bounds each attempt with a watchdog timeout
(a hung dispatch raises :class:`DispatchTimeout` instead of blocking the
compute thread forever), sleeps an exponential backoff with jitter
between attempts, and honors a wall-clock budget — including the
admission deadlines of the requests being served, so the service never
retries work whose futures nobody can use anymore.

:class:`DeadlineExceeded` is also the typed error a request fails with
when its admission deadline passes before dispatch (satellite: fail
expired requests fast instead of computing for an abandoned future).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional, Tuple


class DeadlineExceeded(Exception):
    """The request's wall-clock deadline passed before (or during) the
    work that would have resolved its future."""


class DispatchTimeout(Exception):
    """A dispatch attempt exceeded the watchdog timeout.  Retryable: the
    hung attempt is abandoned on its daemon thread and the call is
    re-issued."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How a failing dispatch/commit is retried.

    max_attempts:  total attempts (1 = no retry).
    backoff_s:     base sleep before attempt N+1; grows by
                   ``backoff_factor ** (N-1)`` with up to ``jitter``
                   relative random spread.
    watchdog_s:    per-attempt timeout; ``None`` runs attempts inline
                   with no watchdog thread (zero overhead).
    budget_s:      total wall-clock budget across all attempts; the
                   per-call ``deadline`` (min admission deadline of the
                   batch) tightens it further.
    no_retry:      exception types that fail immediately (programming
                   errors and deadline misses are not transient).
    """

    max_attempts: int = 3
    backoff_s: float = 0.01
    backoff_factor: float = 2.0
    jitter: float = 0.1
    watchdog_s: Optional[float] = None
    budget_s: Optional[float] = None
    no_retry: Tuple[type, ...] = (
        ValueError, TypeError, KeyError, DeadlineExceeded)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0, got {self.backoff_s}")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}")
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        if self.watchdog_s is not None and self.watchdog_s <= 0:
            raise ValueError(
                f"watchdog_s must be > 0, got {self.watchdog_s}")
        if self.budget_s is not None and self.budget_s <= 0:
            raise ValueError(f"budget_s must be > 0, got {self.budget_s}")

    def retryable(self, exc: BaseException) -> bool:
        # TransientCapacityError is a CapacityError (a ValueError) but is
        # explicitly transient — it must survive the no_retry screen
        from repro.resilience.faults import TransientCapacityError
        if isinstance(exc, TransientCapacityError):
            return True
        return not isinstance(exc, tuple(self.no_retry))

    def delay_s(self, attempt: int, u: float = 0.0) -> float:
        """Backoff before the attempt after ``attempt`` (1-based); ``u``
        in [0, 1) spreads the jitter."""
        return (self.backoff_s * (self.backoff_factor ** (attempt - 1))
                * (1.0 + self.jitter * u))


def call_with_timeout(fn: Callable, timeout_s: float):
    """Run ``fn()`` on a daemon thread, waiting at most ``timeout_s``.

    On expiry raises :class:`DispatchTimeout`; the hung attempt keeps
    running on its abandoned thread (its result is discarded) so a stuck
    device call cannot wedge the service's compute thread."""
    box = []
    done = threading.Event()

    def run():
        try:
            box.append((True, fn()))
        except BaseException as e:      # noqa: BLE001 — relayed below
            box.append((False, e))
        finally:
            done.set()

    t = threading.Thread(target=run, daemon=True, name="resilience-watchdog")
    t.start()
    if not done.wait(timeout_s):
        raise DispatchTimeout(
            f"dispatch exceeded watchdog timeout {timeout_s:.3f}s")
    ok, val = box[0]
    if ok:
        return val
    raise val


def run_with_policy(fn: Callable, policy: Optional[RetryPolicy], *,
                    clock: Callable[[], float] = time.monotonic,
                    deadline: Optional[float] = None,
                    rng=None, on_retry=None,
                    sleep: Callable[[float], None] = time.sleep):
    """Run ``fn()`` under ``policy``.

    ``deadline`` is an absolute time on ``clock``; together with
    ``policy.budget_s`` it caps per-attempt watchdog timeouts and
    backoff sleeps, and aborts retries that could not finish in time.
    ``on_retry(attempt, exc)`` fires before each backoff sleep.  With
    ``policy=None`` the call runs once, inline.
    """
    if policy is None:
        return fn()
    t0 = clock()
    budget_end = None
    if policy.budget_s is not None:
        budget_end = t0 + policy.budget_s
    if deadline is not None:
        budget_end = deadline if budget_end is None else min(
            budget_end, deadline)
    last: Optional[BaseException] = None
    for attempt in range(1, policy.max_attempts + 1):
        timeout = policy.watchdog_s
        if budget_end is not None:
            remaining = budget_end - clock()
            if remaining <= 0.0:
                if last is not None:
                    raise last
                raise DeadlineExceeded(
                    "wall-clock budget exhausted before dispatch")
            timeout = remaining if timeout is None else min(
                timeout, remaining)
        try:
            if timeout is not None:
                return call_with_timeout(fn, timeout)
            return fn()
        except Exception as e:          # noqa: BLE001 — policy filters
            last = e
            if attempt >= policy.max_attempts or not policy.retryable(e):
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            d = policy.delay_s(
                attempt, u=(rng.random() if rng is not None else 0.0))
            if budget_end is not None:
                d = min(d, max(budget_end - clock(), 0.0))
            if d > 0:
                sleep(d)
    raise last                          # pragma: no cover — loop always exits
