"""Per-bucket circuit breaker with half-open probing.

Each admission bucket gets its own :class:`CircuitBreaker` (one sick
compiled shape must not blind the healthy ones).  The breaker trips OPEN
after ``failure_threshold`` consecutive failures — a success slower than
``latency_threshold_s`` counts as a failure, so a silently-degrading
device also trips it.  While OPEN the front end sheds the bucket's
requests to the degraded tier (see :mod:`repro.resilience.degrade`).
After ``cooldown_s`` the breaker admits ``half_open_probes`` probe
dispatches; one success closes it, one failure re-opens it.

State transitions are emitted as the ``breaker_state`` gauge
(0 = closed, 1 = half-open, 2 = open) labelled by bucket.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional

CLOSED = "closed"
HALF_OPEN = "half-open"
OPEN = "open"

STATE_CODES = {CLOSED: 0.0, HALF_OPEN: 1.0, OPEN: 2.0}


class BreakerOpen(RuntimeError):
    """The bucket's circuit breaker is open and no degraded tier is
    available for the request."""


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    failure_threshold: int = 5
    cooldown_s: float = 1.0
    latency_threshold_s: Optional[float] = None
    half_open_probes: int = 1

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be >= 1, got "
                f"{self.failure_threshold}")
        if self.cooldown_s <= 0:
            raise ValueError(
                f"cooldown_s must be > 0, got {self.cooldown_s}")
        if self.latency_threshold_s is not None \
                and self.latency_threshold_s <= 0:
            raise ValueError(
                f"latency_threshold_s must be > 0, got "
                f"{self.latency_threshold_s}")
        if self.half_open_probes < 1:
            raise ValueError(
                f"half_open_probes must be >= 1, got "
                f"{self.half_open_probes}")


class CircuitBreaker:
    """CLOSED -> OPEN -> HALF_OPEN state machine; thread-safe, clock
    injected for tests."""

    def __init__(self, config: BreakerConfig, *,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition=None):
        self.config = config
        self.clock = clock
        self.on_transition = on_transition  # callable(state) | None
        self._lock = threading.Lock()
        self._state = CLOSED
        self._streak = 0                # consecutive failures (incl. slow)
        self._opened_at = 0.0
        self._probes = 0                # probes admitted while half-open
        self.n_opens = 0

    # -- internal (lock held) -------------------------------------------
    def _poll(self):
        if self._state == OPEN and \
                self.clock() - self._opened_at >= self.config.cooldown_s:
            self._probes = 0
            self._set(HALF_OPEN)

    def _set(self, state: str):
        if state == self._state:
            return
        self._state = state
        hook = self.on_transition
        if hook is not None:
            try:
                hook(state)
            except Exception:           # observability must not re-raise
                pass

    def _trip(self):
        self._opened_at = self.clock()
        self.n_opens += 1
        self._streak = 0
        self._set(OPEN)

    def _note_failure(self):
        if self._state == HALF_OPEN:
            self._trip()
            return
        self._streak += 1
        if self._state == CLOSED and \
                self._streak >= self.config.failure_threshold:
            self._trip()

    # -- public ---------------------------------------------------------
    @property
    def state(self) -> str:
        with self._lock:
            self._poll()
            return self._state

    def allow(self) -> bool:
        """May a dispatch proceed right now?  Admits everything while
        CLOSED, nothing while OPEN (pre-cooldown), and up to
        ``half_open_probes`` probes while HALF_OPEN."""
        with self._lock:
            self._poll()
            if self._state == CLOSED:
                return True
            if self._state == HALF_OPEN and \
                    self._probes < self.config.half_open_probes:
                self._probes += 1
                return True
            return False

    def record_success(self, latency_s: Optional[float] = None):
        with self._lock:
            cfg = self.config
            if cfg.latency_threshold_s is not None \
                    and latency_s is not None \
                    and latency_s > cfg.latency_threshold_s:
                self._note_failure()    # slow success counts as failure
                return
            self._streak = 0
            if self._state == HALF_OPEN:
                self._set(CLOSED)

    def record_failure(self):
        with self._lock:
            self._note_failure()


def _bucket_label(key) -> str:
    n_cap = getattr(key, "n_cap", None)
    m_cap = getattr(key, "m_cap", None)
    if n_cap is not None and m_cap is not None:
        return f"{n_cap}x{m_cap}"
    return str(key)


class BreakerBoard:
    """One breaker per bucket, lazily created; transitions emitted as the
    ``breaker_state`` gauge through the telemetry hub."""

    def __init__(self, config: BreakerConfig, *,
                 clock: Callable[[], float] = time.monotonic,
                 telemetry=None):
        self.config = config
        self.clock = clock
        self.telemetry = telemetry
        self._lock = threading.Lock()
        self._breakers: Dict[object, CircuitBreaker] = {}

    def breaker(self, key) -> CircuitBreaker:
        with self._lock:
            br = self._breakers.get(key)
            if br is None:
                label = _bucket_label(key)
                br = CircuitBreaker(
                    self.config, clock=self.clock,
                    on_transition=lambda s, label=label:
                        self._emit(label, s))
                self._breakers[key] = br
                self._emit(label, CLOSED)
            return br

    def _emit(self, label: str, state: str):
        tel = self.telemetry
        if tel is not None and tel.enabled:
            tel.gauge("breaker_state", STATE_CODES[state],
                      {"bucket": label})

    def allow(self, key) -> bool:
        return self.breaker(key).allow()

    def record_success(self, key, latency_s: Optional[float] = None):
        self.breaker(key).record_success(latency_s)

    def record_failure(self, key):
        self.breaker(key).record_failure()

    def state(self, key) -> str:
        return self.breaker(key).state

    def states(self) -> Dict[str, str]:
        with self._lock:
            items = list(self._breakers.items())
        return {_bucket_label(k): br.state for k, br in items}

    @property
    def n_opens(self) -> int:
        """Total CLOSED/HALF_OPEN -> OPEN transitions across all buckets."""
        with self._lock:
            return sum(br.n_opens for br in self._breakers.values())
