"""Degraded-tier results served while a bucket's breaker is open (or a
batch has exhausted its retries).

Two modes, tried in the order configured by
``ServiceConfig(degrade_modes=...)``:

* ``"stale"`` — the last *committed* partition from the result store,
  marked ``stale=True`` with its age in ``staleness_s``.  The partition
  did carry the zero-internally-disconnected guarantee when committed,
  but it no longer reflects the current graph.
* ``"lpa"``   — a fresh label-propagation fast path
  (:func:`repro.core.lpa.lpa_run`), flagged ``quality='degraded'``.
  LPA can and does produce internally-disconnected communities — that
  is exactly the failure mode the paper's refinement fixes.

Either way the result is a :class:`DegradedResult`, never a
:class:`StoreEntry`: ``guarantee`` is always ``False``, degraded output
is never committed back to the store, and callers can (and the chaos
driver does) separate it from full-quality results by type.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.lpa import lpa_run
from repro.core.modularity import modularity


@dataclasses.dataclass
class DegradedResult:
    """A reduced-quality answer, explicitly NOT carrying the paper's
    zero-internally-disconnected guarantee (``guarantee=False``)."""

    graph_id: str
    C: np.ndarray                 # labels over the padded node axis
    n_communities: int
    q: float                      # modularity of the served partition
    mode: str                     # "stale" | "lpa"
    quality: str                  # "stale" | "degraded"
    stale: bool
    staleness_s: float            # age of the served partition (0 if fresh)
    version: int = 0              # store version served (stale mode only)
    n_disconnected: Optional[int] = None  # None = not evaluated (lpa)
    guarantee: bool = False


def stale_result(graph_id: str, entry, *, now: float) -> DegradedResult:
    """Serve the last committed partition from a store entry."""
    return DegradedResult(
        graph_id=graph_id,
        C=np.asarray(entry.C),
        n_communities=int(entry.n_communities),
        q=float(entry.q),
        mode="stale",
        quality="stale",
        stale=True,
        staleness_s=max(float(now) - float(entry.t_stored), 0.0),
        version=int(entry.version),
        n_disconnected=int(entry.n_disconnected),
    )


def lpa_result(graph_id: str, graph, *, max_iters: int = 50
               ) -> DegradedResult:
    """Compute a fresh LPA fast-path partition for ``graph``."""
    labels, _ = lpa_run(graph, max_iters=max_iters)
    C = np.asarray(labels, dtype=np.int32)
    mask = np.asarray(graph.node_mask())
    n_comms = int(C[mask].max()) + 1 if bool(mask.any()) else 0
    q = float(modularity(graph.src, graph.dst, graph.w, labels, graph.nv))
    return DegradedResult(
        graph_id=graph_id,
        C=C,
        n_communities=n_comms,
        q=q,
        mode="lpa",
        quality="degraded",
        stale=False,
        staleness_s=0.0,
        n_disconnected=None,
    )
