"""Degraded-tier results served while a bucket's breaker is open (or a
batch has exhausted its retries).

Two modes, tried in the order configured by
``ServiceConfig(degrade_modes=...)``:

* ``"stale"`` — the last *committed* partition from the result store,
  marked ``stale=True`` with its age in ``staleness_s``.  The partition
  carries the :class:`repro.core.portfolio.QualityContract` of the tier
  that produced it, but it no longer reflects the current graph.
* ``"lpa"``   — the portfolio's **fast tier**
  (:func:`repro.core.portfolio.run_detection` with
  ``algorithm='fast'``), flagged ``quality='degraded'``.  This is the
  SAME code path a request pinned to the fast tier takes, so LPA-under-
  breaker and LPA-as-requested-tier are bit-identical on the same graph
  and share one contract shape.  LPA can and does produce
  internally-disconnected communities — exactly the failure mode the
  paper's refinement fixes — and ``n_disconnected`` reports the measured
  count instead of pretending otherwise.

Either way the result is a :class:`DegradedResult`, never a
:class:`StoreEntry`: ``guarantee`` is always ``False``, degraded output
is never committed back to the store, and callers can (and the chaos
driver does) separate it from full-quality results by type.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.portfolio import QualityContract, contract_for


@dataclasses.dataclass
class DegradedResult:
    """A reduced-quality answer, explicitly NOT carrying the paper's
    zero-internally-disconnected guarantee (``guarantee=False``).
    ``contract`` records the producing tier's flags — the stale mode
    keeps the committed entry's contract (true when committed, now
    stale), the lpa mode carries the fast tier's all-False contract."""

    graph_id: str
    C: np.ndarray                 # labels over the padded node axis
    n_communities: int
    q: float                      # modularity of the served partition
    mode: str                     # "stale" | "lpa"
    quality: str                  # "stale" | "degraded"
    stale: bool
    staleness_s: float            # age of the served partition (0 if fresh)
    version: int = 0              # store version served (stale mode only)
    n_disconnected: Optional[int] = None  # None = unknown (legacy entries)
    guarantee: bool = False
    contract: Optional[QualityContract] = None


def stale_result(graph_id: str, entry, *, now: float) -> DegradedResult:
    """Serve the last committed partition from a store entry."""
    return DegradedResult(
        graph_id=graph_id,
        C=np.asarray(entry.C),
        n_communities=int(entry.n_communities),
        q=float(entry.q),
        mode="stale",
        quality="stale",
        stale=True,
        staleness_s=max(float(now) - float(entry.t_stored), 0.0),
        version=int(entry.version),
        n_disconnected=int(entry.n_disconnected),
        contract=contract_for(getattr(entry, "algorithm", "standard")),
    )


def lpa_result(graph_id: str, graph, *, options=None,
               telemetry=None) -> DegradedResult:
    """Compute a fresh fast-tier partition for ``graph`` through the
    portfolio dispatch — one code path with requested-tier LPA.

    ``options``: the service's :class:`repro.core.api.DetectOptions`
    (backend knobs carry over; the algorithm is forced to ``'fast'`` and
    the mesh is dropped — the degraded path runs single-device on the
    compute thread).
    """
    from repro.core.api import DetectOptions
    from repro.core.portfolio import run_detection
    opts = (options or DetectOptions()).replace(algorithm="fast", mesh=None)
    det = run_detection(graph, opts, telemetry=telemetry)
    return DegradedResult(
        graph_id=graph_id,
        C=np.asarray(det.labels, dtype=np.int32),
        n_communities=int(det.n_communities),
        q=float(det.modularity),
        mode="lpa",
        quality="degraded",
        stale=False,
        staleness_s=0.0,
        n_disconnected=int(det.n_disconnected),
        contract=det.contract,
    )
