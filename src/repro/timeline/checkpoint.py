"""Durable timeline-service checkpoints.

Persists the full temporal-tracking state of a :class:`repro.service.
frontend.ServiceFrontend` — every resident :class:`~repro.service.store.
StoreEntry` (graph arrays, membership, deferred tombstones, version) plus
the :class:`~repro.timeline.tracker.TimelineManager`'s id maps, matcher
state, snapshots, community timelines and lifecycle events — through the
same atomic tmp->rename checkpoint store the train loop uses
(:mod:`repro.checkpoint.store`).

Restore rebuilds warm store entries via :meth:`ResultStore.restore_entry`
(which deliberately does NOT fire the commit hook: the timeline history
comes from the checkpoint, not from replaying the restore as a fresh
snapshot), then wipes-and-loads the manager with
:meth:`TimelineManager.load_state`.  After a round trip, every
``membership_at``/``timeline``/``lifecycle_events`` answer is identical
to the pre-checkpoint service, and warm updates resume from the exact
entry version that was saved.

Checkpoint at a quiescent point: in-flight windows (pending id-map
stamps) are transient hints and are not captured.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.checkpoint.store import (
    CheckpointCorrupt, latest_step, load_checkpoint_arrays, save_checkpoint,
)
from repro.graph.container import Graph

_KIND = "timeline-service"


def _entry_arrays(arrays, graphs_meta, gi, gid, entry, *, evicted=False):
    g = entry.graph
    arrays[f"graph{gi}.src"] = np.asarray(g.src, np.int32)
    arrays[f"graph{gi}.dst"] = np.asarray(g.dst, np.int32)
    arrays[f"graph{gi}.w"] = np.asarray(g.w, np.float32)
    arrays[f"graph{gi}.C"] = np.asarray(entry.C, np.int32)
    arrays[f"graph{gi}.deferred"] = np.asarray(entry.deferred, np.int64)
    meta = dict(
        index=gi, graph_id=gid,
        n_nodes=int(g.n_nodes), n_cap=int(g.n_cap), m_cap=int(g.m_cap),
        n_communities=int(entry.n_communities),
        n_disconnected=int(entry.n_disconnected),
        q=float(entry.q), version=int(entry.version),
        algorithm=str(entry.algorithm))
    if evicted:
        meta["evicted"] = True
    graphs_meta.append(meta)


def save_service_checkpoint(frontend, ckpt_dir: str, *,
                            step: Optional[int] = None,
                            extra_entries=None) -> int:
    """Write one atomic checkpoint of ``frontend``'s store + timelines.

    ``step`` defaults to ``latest_step + 1`` (0 for a fresh dir).
    ``extra_entries`` (gid -> StoreEntry) are evicted-but-warm entries to
    write back alongside the resident ones (the auto-checkpointer's
    eviction buffer); resident entries win on gid collision.  Returns the
    step written.
    """
    if step is None:
        prev = latest_step(ckpt_dir)
        step = 0 if prev is None else prev + 1
    arrays = {}
    graphs_meta = []
    store = frontend.store
    gi = 0
    written = set()
    for gid in store.graph_ids():
        entry = store.get(gid)
        if entry is None:  # evicted between listing and get
            continue
        _entry_arrays(arrays, graphs_meta, gi, gid, entry)
        written.add(gid)
        gi += 1
    for gid, entry in (extra_entries or {}).items():
        if gid in written:
            continue
        _entry_arrays(arrays, graphs_meta, gi, gid, entry, evicted=True)
        gi += 1
    tl_meta = {}
    tl = getattr(frontend, "timelines", None)
    if tl is not None:
        tl_arrays, tl_meta = tl.state()
        for k, v in tl_arrays.items():
            arrays[f"tl.{k}"] = v
    save_checkpoint(ckpt_dir, step, arrays, extra=dict(
        kind=_KIND, graphs=graphs_meta, timeline=tl_meta))
    return step


def restore_service_checkpoint(frontend, ckpt_dir: str, *,
                               step: Optional[int] = None) -> Optional[int]:
    """Restore store entries + timeline state from a checkpoint.

    Decode happens build-then-apply: every graph and array is read (and
    validated) before the first store mutation, so a torn/partial
    checkpoint raises :class:`CheckpointCorrupt` without half-restoring
    the service — the caller (startup recovery) falls back to the
    previous snapshot.  Entries saved from the eviction write-back
    buffer are applied before resident ones, leaving residents
    most-recently-used if the restore overflows the store's LRU cap.

    Returns the restored step, or ``None`` when no checkpoint exists.
    """
    arrays, extra, step = load_checkpoint_arrays(ckpt_dir, step=step)
    if arrays is None:
        return None
    if extra.get("kind") != _KIND:
        raise ValueError(
            f"not a {_KIND} checkpoint: kind={extra.get('kind')!r}")
    try:
        items = []
        order = sorted(extra["graphs"],
                       key=lambda m: 0 if m.get("evicted") else 1)
        for gm in order:
            gi, gid = gm["index"], gm["graph_id"]
            g = Graph(
                src=arrays[f"graph{gi}.src"].astype(np.int32),
                dst=arrays[f"graph{gi}.dst"].astype(np.int32),
                w=arrays[f"graph{gi}.w"].astype(np.float32),
                n_nodes=np.int32(gm["n_nodes"]),
                n_cap=int(gm["n_cap"]), m_cap=int(gm["m_cap"]))
            items.append((gid, g, arrays[f"graph{gi}.C"].astype(np.int32),
                          gm, arrays[f"graph{gi}.deferred"]))
        tl_arrays = {k[len("tl."):]: v for k, v in arrays.items()
                     if k.startswith("tl.")}
    except KeyError as e:
        raise CheckpointCorrupt(
            f"service checkpoint step {step} is missing key {e}") from e
    store = frontend.store
    for gid, g, C, gm, deferred in items:
        store.restore_entry(
            gid, g, C,
            n_communities=gm["n_communities"],
            n_disconnected=gm["n_disconnected"],
            q=gm["q"], version=gm["version"],
            algorithm=gm.get("algorithm"),
            deferred=deferred)
    tl = getattr(frontend, "timelines", None)
    tl_meta = extra.get("timeline") or {}
    if tl is not None and tl_meta:
        tl.load_state(tl_arrays, tl_meta)
    return step
