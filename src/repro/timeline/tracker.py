"""Temporal community tracking: the service-side timeline manager.

:class:`TimelineManager` turns the service's store commits into a
community *timeline*.  It hangs off the :class:`repro.service.store.
ResultStore` commit hook (``on_commit``), so every path that refreshes
an entry — fresh detects, immediate warm updates, the vmapped batched
update path, deferred-compaction flushes — lands here exactly once,
with the :class:`~repro.service.store.UpdatePlan` that produced it:

1. the plan's ``id_map`` (and deferred tombstones) fold into the
   graph's :class:`repro.timeline.idmap.ExternalIdMap`, so vertices
   keep their external ids across arbitrarily many compactions;
2. the committed membership is regrouped into external-id member sets
   (deferred tombstones excluded);
3. the weighted-Jaccard matcher (:mod:`repro.timeline.matcher`)
   assigns persistent community ids against the previous snapshot and
   emits lifecycle events;
4. the snapshot, community rows and events land in the bounded
   :class:`repro.timeline.store.TimelineStore`, subscribers are
   notified, and telemetry counters/histograms tick.

Timeline retention is governed HERE (``TimelineConfig`` bounds), never
by ResultStore eviction: an LRU/TTL-evicted compute entry keeps its
history queryable until :meth:`TimelineManager.drop_graph` or the
bounded deques roll over.

:func:`translate_window` + :class:`WindowedIngest` are the ingestion
side: they fold a window of external-id :class:`repro.data.streams.
GraphEvent`\\ s into ONE :class:`repro.core.dynamic.GraphUpdate` in the
service's internal id space, mirroring the compaction contract (and the
store's deferred-compaction flush rule) deterministically so client and
service never need an id handshake.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dynamic import GraphUpdate
from repro.timeline.idmap import ExternalIdMap, compose_batch_maps
from repro.timeline.matcher import (
    LifecycleEvent, Members, match_snapshots,
)
from repro.timeline.store import (
    CommunityTimeline, Snapshot, TimelineStore,
)


@dataclasses.dataclass(frozen=True)
class TimelineConfig:
    """Matcher + retention knobs (mirrored from ServiceConfig)."""

    jaccard_min: float = 0.1
    weight_by_degree: bool = False
    max_snapshots: int = 64
    max_events: int = 4096
    max_rows: int = 256
    max_communities: int = 4096

    def __post_init__(self):
        if not (0.0 < self.jaccard_min <= 1.0):
            raise ValueError(
                f"jaccard_min must be in (0, 1], got {self.jaccard_min}")


class _Track:
    """Per-graph tracking state (guarded by the manager lock)."""

    __slots__ = ("idmap", "prev", "dead")

    def __init__(self, idmap: ExternalIdMap):
        self.idmap = idmap
        self.prev: Dict[int, Members] = {}   # persistent id -> members
        self.dead: set = set()               # deferred tombstone internals


class TimelineManager:
    """Thread-safe: commits arrive on the compute thread, queries and
    subscriptions from anywhere."""

    def __init__(self, config: Optional[TimelineConfig] = None, *,
                 telemetry=None, clock=None):
        import time
        self.config = config or TimelineConfig()
        self.telemetry = telemetry
        self.clock = clock or time.time
        self.store = TimelineStore(
            max_snapshots=self.config.max_snapshots,
            max_events=self.config.max_events,
            max_rows=self.config.max_rows,
            max_communities=self.config.max_communities)
        self._lock = threading.RLock()
        self._graphs: Dict[str, _Track] = {}
        self._times: Dict[str, float] = {}        # pending snapshot stamps
        self._pending_maps: Dict[str, Tuple[np.ndarray, int]] = {}
        self._pending_adds: Dict[str, List[int]] = {}
        self._next_cid = 0
        self._subs: List[Callable[[List[LifecycleEvent]], None]] = []
        self.n_snapshots = 0
        self.n_lifecycle = 0
        self.n_idmap_resets = 0
        self.n_binding_mismatches = 0
        self.n_subscriber_errors = 0

    # -- ingestion-side hints ---------------------------------------------
    def set_time(self, graph_id: str, t: Optional[float]):
        """Stamp the NEXT commit for ``graph_id`` with event-time ``t``
        (the window end).  Unstamped commits use wall-clock time."""
        with self._lock:
            if t is None:
                self._times.pop(graph_id, None)
            else:
                self._times[graph_id] = float(t)

    def ensure_track(self, graph_id: str, n: int) -> ExternalIdMap:
        """The graph's live :class:`ExternalIdMap`, creating identity
        tracking over ``[0, n)`` on first sight (the ingest side needs
        the map to translate a window BEFORE the first commit it
        observes)."""
        with self._lock:
            trk = self._graphs.get(graph_id)
            if trk is None:
                trk = _Track(ExternalIdMap(int(n)))
                self._graphs[graph_id] = trk
            return trk.idmap

    def register_pending_adds(self, graph_id: str, externals: Sequence[int]):
        """Bind client-chosen external ids to the vertex-addition slots of
        the next commit, in claim order."""
        with self._lock:
            self._pending_adds[graph_id] = [int(e) for e in externals]

    def register_rebucket(self, graph_id: str, batches, n_nodes: int):
        """A capacity overflow re-routed ``batches`` into a fresh detect
        (:class:`repro.service.frontend.ServiceFrontend`'s rebucket
        continuation).  Record the composed old->new id map so the
        detect's commit extends the external-id history instead of
        resetting it."""
        id_map, n_final = compose_batch_maps(int(n_nodes), batches)
        with self._lock:
            self._pending_maps[graph_id] = (id_map, n_final)

    # -- the commit hook ---------------------------------------------------
    def observe_commit(self, graph_id: str, entry, plan) -> None:
        """ResultStore ``on_commit``: fold the remap, match communities,
        record the snapshot.  ``plan`` is None for fresh detect puts."""
        events: List[LifecycleEvent] = []
        with self._lock:
            t = self._times.pop(graph_id, None)
            if t is None:
                t = float(self.clock())
            pending_adds = self._pending_adds.pop(graph_id, None)
            n = int(entry.graph.n_nodes)
            trk = self._fold_idmap(graph_id, entry, plan, n, pending_adds)
            new_members = self._extract_members(entry, trk, n)
            labels = sorted(new_members)
            member_list = [new_members[lab] for lab in labels]
            assigned, events = match_snapshots(
                trk.prev, member_list, t=t, graph_id=graph_id,
                jaccard_min=self.config.jaccard_min,
                next_id=self._mint, on_overlap=self._observe_overlap)
            trk.prev = {assigned[i]: member_list[i]
                        for i in range(len(member_list))}
            self.store.record_snapshot(
                graph_id, t, list(zip(assigned, member_list)), events,
                n_disconnected=int(entry.n_disconnected))
            self.n_snapshots += 1
            self.n_lifecycle += len(events)
        if self.telemetry is not None:
            self.telemetry.counter("timeline_snapshots", 1)
            kinds: Dict[str, int] = {}
            for ev in events:
                kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
            for kind, k in kinds.items():
                self.telemetry.counter("timeline_events", k,
                                       {"kind": kind})
        if events:
            for fn in list(self._subs):
                try:
                    fn(events)
                except Exception:
                    self.n_subscriber_errors += 1

    def _fold_idmap(self, graph_id: str, entry, plan, n: int,
                    pending_adds: Optional[List[int]]) -> _Track:
        trk = self._graphs.get(graph_id)
        if plan is None:
            pending = self._pending_maps.pop(graph_id, None)
            if trk is None:
                trk = _Track(ExternalIdMap(n))
                self._graphs[graph_id] = trk
            elif pending is not None:
                id_map, n_final = pending
                if n_final != n:
                    # the rebucket rebuild diverged from what we composed
                    # (shouldn't happen); reset rather than corrupt
                    self.n_idmap_resets += 1
                    trk.idmap = ExternalIdMap(n)
                else:
                    self._apply_map(trk, id_map, n, pending_adds)
                trk.dead.clear()
            elif trk.idmap.n_slots == n and not trk.dead:
                pass   # same vertex set re-detected (edge-overflow rebucket)
            else:
                # the client replaced the graph wholesale: externals from
                # the old life are unrecoverable, start a fresh id space
                self.n_idmap_resets += 1
                trk.idmap = ExternalIdMap(n)
                trk.dead.clear()
            return trk
        if trk is None:                      # update before any detect seen
            trk = _Track(ExternalIdMap(n))
            self._graphs[graph_id] = trk
            return trk
        self._apply_map(trk, plan.id_map, n, pending_adds)
        deferred_removed = getattr(plan, "deferred_removed", None)
        if deferred_removed is not None and len(deferred_removed):
            trk.idmap.retire_internal(np.asarray(deferred_removed))
        deferred_after = getattr(entry, "deferred", None)
        trk.dead = (set(np.asarray(deferred_after).tolist())
                    if deferred_after is not None else set())
        return trk

    def _apply_map(self, trk: _Track, id_map, n: int,
                   pending_adds: Optional[List[int]]):
        if id_map is None and trk.idmap.n_slots == n and not pending_adds:
            return
        fresh, _ = trk.idmap.apply(id_map, n, fresh_ids=pending_adds)
        if pending_adds and fresh != list(pending_adds):
            self.n_binding_mismatches += 1

    def _extract_members(self, entry, trk: _Track,
                         n: int) -> Dict[int, Members]:
        if trk.idmap.n_slots != n:
            # defensive resync (a commit observed without its remap, e.g.
            # a hook registered mid-life); grow/shrink via identity
            self.n_idmap_resets += 1
            trk.idmap.apply(None, n)
        ext = trk.idmap.externals()
        C = np.asarray(entry.C)[:n]
        live = ext >= 0                      # deferred tombstones excluded
        if self.config.weight_by_degree:
            g = entry.graph
            src = np.asarray(g.src)
            w = np.asarray(g.w)
            sel = src < g.n_cap
            deg = np.bincount(src[sel], weights=w[sel], minlength=n)[:n]
            weight = np.maximum(deg, 1.0)
        else:
            weight = np.ones(n)
        members: Dict[int, Members] = {}
        for i in np.flatnonzero(live):
            members.setdefault(int(C[i]), {})[int(ext[i])] = float(weight[i])
        return members

    def _mint(self) -> int:
        cid = self._next_cid
        self._next_cid += 1
        return cid

    def _observe_overlap(self, j: float):
        if self.telemetry is not None:
            self.telemetry.observe("matcher_overlap", j)

    # -- queries -----------------------------------------------------------
    def membership_at(self, graph_id: str, external: int,
                      t: Optional[float] = None) -> Optional[int]:
        with self._lock:
            return self.store.membership_at(graph_id, external, t)

    def timeline(self, community_id: int) -> Optional[CommunityTimeline]:
        with self._lock:
            return self.store.timeline(community_id)

    def communities(self, graph_id: Optional[str] = None, *,
                    alive_only: bool = False) -> List[CommunityTimeline]:
        with self._lock:
            return self.store.communities(graph_id, alive_only=alive_only)

    def lifecycle_events(self, graph_id: Optional[str] = None, *,
                         kind: Optional[str] = None) -> List[LifecycleEvent]:
        with self._lock:
            return self.store.lifecycle_events(graph_id, kind=kind)

    def snapshots(self, graph_id: str) -> List[Snapshot]:
        with self._lock:
            return self.store.snapshots(graph_id)

    def external_ids(self, graph_id: str) -> Optional[np.ndarray]:
        with self._lock:
            trk = self._graphs.get(graph_id)
            return None if trk is None else trk.idmap.externals()

    def internal_of(self, graph_id: str, external: int) -> Optional[int]:
        with self._lock:
            trk = self._graphs.get(graph_id)
            return None if trk is None else trk.idmap.internal_of(external)

    def subscribe(self, fn: Callable[[List[LifecycleEvent]], None]
                  ) -> Callable[[List[LifecycleEvent]], None]:
        with self._lock:
            self._subs.append(fn)
        return fn

    def unsubscribe(self, fn) -> bool:
        with self._lock:
            try:
                self._subs.remove(fn)
                return True
            except ValueError:
                return False

    def drop_graph(self, graph_id: str) -> int:
        """The ONE retention control for timeline history (ResultStore
        eviction intentionally does not reach here)."""
        with self._lock:
            self._graphs.pop(graph_id, None)
            self._times.pop(graph_id, None)
            self._pending_maps.pop(graph_id, None)
            self._pending_adds.pop(graph_id, None)
            return self.store.drop_graph(graph_id)

    # -- checkpointing ------------------------------------------------------
    def state(self) -> Tuple[Dict[str, np.ndarray], dict]:
        """Snapshot every durable tracking structure.

        Returns ``(arrays, meta)``: bulky state (id maps, snapshot
        membership, matcher prev-sets, community rows) as a flat dict of
        numpy arrays, everything else JSON-able in ``meta`` — the split
        :func:`repro.checkpoint.store.save_checkpoint` wants.  Transient
        per-commit hints (pending snapshot stamps, pending add bindings,
        rebucket maps) are deliberately NOT captured: checkpoint at a
        quiescent point (no in-flight window).
        """
        with self._lock:
            arrays: Dict[str, np.ndarray] = {}
            gids = sorted(self._graphs)
            meta: dict = {
                "graphs": gids,
                "next_cid": int(self._next_cid),
                "counters": dict(
                    n_snapshots=int(self.n_snapshots),
                    n_lifecycle=int(self.n_lifecycle),
                    n_idmap_resets=int(self.n_idmap_resets),
                    n_binding_mismatches=int(self.n_binding_mismatches),
                    n_subscriber_errors=int(self.n_subscriber_errors)),
                "idmap_next": {},
            }
            for gi, gid in enumerate(gids):
                trk = self._graphs[gid]
                ext, nxt, retired = trk.idmap.state()
                arrays[f"g{gi}.idmap_ext"] = ext
                arrays[f"g{gi}.idmap_retired"] = retired
                meta["idmap_next"][gid] = int(nxt)
                arrays[f"g{gi}.dead"] = np.asarray(
                    sorted(trk.dead), np.int64)
                pid, pext, pw = [], [], []
                for p in sorted(trk.prev):
                    for e, w in trk.prev[p].items():
                        pid.append(p)
                        pext.append(e)
                        pw.append(w)
                arrays[f"g{gi}.prev_pid"] = np.asarray(pid, np.int64)
                arrays[f"g{gi}.prev_ext"] = np.asarray(pext, np.int64)
                arrays[f"g{gi}.prev_w"] = np.asarray(pw, np.float64)
            st = self.store
            meta["store_counters"] = dict(
                n_snapshots=int(st.n_snapshots),
                n_events=int(st.n_events),
                n_truncated_communities=int(st.n_truncated_communities))
            sgids = sorted(st._snaps)
            meta["snap_graphs"] = sgids
            meta["snap_meta"] = {}
            for si, gid in enumerate(sgids):
                rows = []
                for j, s in enumerate(st._snaps[gid]):
                    arrays[f"s{si}.{j}.ext"] = np.asarray(s.ext, np.int64)
                    arrays[f"s{si}.{j}.cid"] = np.asarray(s.cid, np.int64)
                    rows.append(dict(t=float(s.t),
                                     n_communities=int(s.n_communities),
                                     n_disconnected=int(s.n_disconnected)))
                meta["snap_meta"][gid] = rows
            comms = []
            for ci, (cid, tl) in enumerate(st._comms.items()):
                comms.append(dict(
                    cid=int(cid), graph_id=tl.graph_id,
                    born_t=float(tl.born_t),
                    dead_t=(None if tl.dead_t is None else float(tl.dead_t)),
                    parents=[int(p) for p in tl.parents],
                    origin=tl.origin))
                arrays[f"c{ci}.rows"] = np.asarray(
                    [list(r) for r in tl.rows], np.float64).reshape(-1, 3)
            meta["communities"] = comms
            meta["events"] = [dict(
                kind=e.kind, t=float(e.t), graph_id=e.graph_id,
                community=int(e.community),
                parents=[int(p) for p in e.parents],
                overlap=float(e.overlap), size=int(e.size))
                for e in st._events]
            return arrays, meta

    def load_state(self, arrays: Dict[str, np.ndarray], meta: dict):
        """Replace ALL tracking state with a :meth:`state` snapshot (the
        restore half — wipe-and-load, not a merge)."""
        from collections import deque

        with self._lock:
            self._graphs.clear()
            self._times.clear()
            self._pending_maps.clear()
            self._pending_adds.clear()
            self._next_cid = int(meta["next_cid"])
            for k, v in meta["counters"].items():
                setattr(self, k, int(v))
            for gi, gid in enumerate(meta["graphs"]):
                trk = _Track(ExternalIdMap.from_state(
                    arrays[f"g{gi}.idmap_ext"],
                    meta["idmap_next"][gid],
                    arrays[f"g{gi}.idmap_retired"]))
                trk.dead = set(
                    int(x) for x in arrays[f"g{gi}.dead"].tolist())
                prev: Dict[int, Members] = {}
                for p, e, w in zip(arrays[f"g{gi}.prev_pid"].tolist(),
                                   arrays[f"g{gi}.prev_ext"].tolist(),
                                   arrays[f"g{gi}.prev_w"].tolist()):
                    prev.setdefault(int(p), {})[int(e)] = float(w)
                trk.prev = prev
                self._graphs[gid] = trk
            st = self.store
            for k, v in meta["store_counters"].items():
                setattr(st, k, int(v))
            st._snaps.clear()
            st._times.clear()
            for si, gid in enumerate(meta["snap_graphs"]):
                dq = deque(maxlen=st.max_snapshots)
                for j, row in enumerate(meta["snap_meta"][gid]):
                    dq.append(Snapshot(
                        t=float(row["t"]),
                        ext=np.asarray(arrays[f"s{si}.{j}.ext"], np.int64),
                        cid=np.asarray(arrays[f"s{si}.{j}.cid"], np.int64),
                        n_communities=int(row["n_communities"]),
                        n_disconnected=int(row["n_disconnected"])))
                st._snaps[gid] = dq
                st._times[gid] = [s.t for s in dq]
            st._comms.clear()
            for ci, cm in enumerate(meta["communities"]):
                rows = arrays[f"c{ci}.rows"]
                st._comms[int(cm["cid"])] = CommunityTimeline(
                    cid=int(cm["cid"]), graph_id=cm["graph_id"],
                    born_t=float(cm["born_t"]),
                    dead_t=(None if cm["dead_t"] is None
                            else float(cm["dead_t"])),
                    parents=tuple(int(p) for p in cm["parents"]),
                    origin=cm["origin"],
                    rows=deque(
                        [(float(r[0]), int(r[1]), float(r[2]))
                         for r in rows.tolist()]))
            st._events = deque(
                (LifecycleEvent(
                    kind=e["kind"], t=float(e["t"]),
                    graph_id=e["graph_id"], community=int(e["community"]),
                    parents=tuple(int(p) for p in e["parents"]),
                    overlap=float(e["overlap"]), size=int(e["size"]))
                 for e in meta["events"]),
                maxlen=st.max_events)


def translate_window(events, *, idmap: ExternalIdMap, entry,
                     compact_window: int = 0
                     ) -> Tuple[GraphUpdate, dict]:
    """Fold one window of external-id events into ONE internal-id
    :class:`GraphUpdate`, mirroring the service's id contract.

    Window folding is set-semantics for vertex ops (a vertex added then
    removed inside the window cancels, with its edges) and net-delta
    semantics for edges (an edge added then deleted nets to nothing).
    Edge endpoints referencing a vertex removed in the same window — or
    never known — are dropped and counted.

    The translation mirrors :func:`repro.core.dynamic.
    apply_vertex_updates`' compaction contract exactly: with
    ``compact_window == 0`` removals shift surviving internals down and
    additions claim ``[n', n'+add)``; with deferral on, ids do NOT
    shift, additions claim ``[n, n+add)``, and the store's
    flush-at-fold-start rule (pending >= window, or additions would
    overflow ``n_cap``) is re-derived here so predicted ids match the
    post-flush space.

    Returns ``(update, stats)``; ``stats['adds_ext']`` lists the client
    externals for the claimed slots in order (feed it to
    :meth:`TimelineManager.register_pending_adds`).
    """
    events = list(events)
    adds: List[int] = []
    removes: List[int] = []
    removed_ext: set = set()
    cancelled: set = set()
    edges: "Dict[Tuple[int, int], float]" = {}
    edge_order: List[Tuple[int, int]] = []
    dropped_vertices = dropped_edges = 0
    add_set: set = set()
    for ev in events:
        kind = ev.kind
        if kind == "vertex_add":
            e = int(ev.u)
            if e in add_set or e in idmap or idmap.is_retired(e):
                dropped_vertices += 1
                continue
            adds.append(e)
            add_set.add(e)
        elif kind == "vertex_del":
            e = int(ev.u)
            if e in add_set:
                add_set.discard(e)
                adds.remove(e)
                cancelled.add(e)
            elif e not in removed_ext and idmap.internal_of(e) is not None:
                removes.append(e)
                removed_ext.add(e)
            else:
                dropped_vertices += 1
        elif kind in ("edge_add", "edge_delta", "edge_del"):
            a, b = int(ev.u), int(ev.v)
            key = (a, b) if a <= b else (b, a)
            dw = float(ev.w) if kind != "edge_del" else -float(ev.w)
            if key not in edges:
                edge_order.append(key)
                edges[key] = 0.0
            edges[key] += dw
        else:
            raise ValueError(f"unknown graph event kind {kind!r}")

    n = int(entry.graph.n_nodes)
    n_cap = int(entry.graph.n_cap)
    deferred = getattr(entry, "deferred", None)
    dead = (np.asarray(deferred, np.int64)
            if deferred is not None else np.empty(0, np.int64))
    defer = int(compact_window) > 0
    # mirror ResultStore's flush-at-fold-start rule exactly (including
    # knob-off with leftover tombstones, e.g. after a checkpoint restore
    # under a different compact_window)
    flush = bool(dead.size
                 and (not defer or dead.size >= int(compact_window)
                      or n + len(adds) > n_cap))
    shift = None
    if flush:
        alive = np.ones(n, bool)
        alive[dead] = False
        shift = np.cumsum(alive) - 1          # pre-flush id -> post-flush
        n -= int(dead.size)

    def current(i: int) -> int:
        return int(shift[i]) if shift is not None else int(i)

    r_int = sorted(current(idmap.internal_of(e)) for e in removes)
    if defer:
        base = n
        rs = None
    else:
        base = n - len(r_int)
        rs = r_int
    add_idx = {e: base + k for k, e in enumerate(adds)}

    u_out, v_out, w_out = [], [], []
    for key in edge_order:
        dw = edges[key]
        if dw == 0.0:
            continue
        ids = []
        ok = True
        for e in key:
            if e in removed_ext or e in cancelled:
                ok = False
                break
            if e in add_idx:
                ids.append(add_idx[e])
                continue
            i = idmap.internal_of(e)
            if i is None:
                ok = False
                break
            i = current(i)
            if rs is not None:
                i -= bisect.bisect_left(rs, i)
            ids.append(i)
        if not ok:
            dropped_edges += 1
            continue
        u_out.append(ids[0])
        v_out.append(ids[1])
        w_out.append(dw)

    upd = GraphUpdate(
        u=np.asarray(u_out, np.int32), v=np.asarray(v_out, np.int32),
        dw=np.asarray(w_out, np.float32), add=len(adds),
        remove=np.asarray(r_int, np.int64))
    stats = dict(
        n_events=len(events),
        adds_ext=list(adds), n_removed=len(r_int),
        n_edges=len(u_out), dropped_edges=dropped_edges,
        dropped_vertices=dropped_vertices, flush_predicted=flush)
    return upd, stats


class WindowedIngest:
    """Time-window batcher over a frontend's :meth:`ingest_window`.

    Feed it a nondecreasing-``t`` stream of :class:`repro.data.streams.
    GraphEvent`\\ s; whenever an event crosses the current window
    boundary the buffered window commits as one snapshot (empty windows
    commit too — a quiet window is still a window, and its snapshot is
    all continuations).  Requires ``ServiceConfig(timeline_enabled=True,
    update_batch_size=1)`` — coarser update batching would fold several
    windows into one snapshot.
    """

    def __init__(self, frontend, graph_id: str, *, window: float,
                 t0: float = 0.0, tenant: Optional[str] = None):
        if window <= 0:
            raise ValueError(f"window must be > 0, got {window}")
        self.frontend = frontend
        self.graph_id = graph_id
        self.window = float(window)
        self.tenant = tenant
        self._end = float(t0) + self.window
        self._buf: List = []
        self.n_windows = 0
        self.n_events = 0

    def ingest(self, event) -> List:
        """Buffer one event; returns the futures of any windows its
        timestamp closed (usually empty or one)."""
        out = []
        while float(event.t) >= self._end:
            out.append(self._commit())
        self._buf.append(event)
        self.n_events += 1
        return out

    def flush(self):
        """Commit the current (partial) window; returns its future."""
        return self._commit()

    def _commit(self):
        events, self._buf = self._buf, []
        t = self._end
        self._end += self.window
        self.n_windows += 1
        kw = {} if self.tenant is None else {"tenant": self.tenant}
        return self.frontend.ingest_window(self.graph_id, events, t=t, **kw)
