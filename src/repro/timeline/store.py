"""Bounded-memory timeline store: snapshots, community rows, events.

Three retention domains, each bounded independently of the
:class:`repro.service.store.ResultStore` (whose LRU/TTL eviction governs
*compute* residency, not history — see the frontend's retention note):

* per graph, a deque of the last ``max_snapshots`` full membership
  snapshots ``(t, sorted external ids, persistent community ids)`` —
  what :meth:`membership_at` answers from;
* per persistent community, a row deque capped at ``max_rows``
  (size/weight trajectory) plus birth/death times;
* one global lifecycle-event deque capped at ``max_events``.

Everything is host-side numpy + plain dicts; reads and writes are
serialized by the owning :class:`repro.timeline.tracker.
TimelineManager`'s lock.
"""
from __future__ import annotations

import bisect
import dataclasses
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.timeline.matcher import LifecycleEvent, Members


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One committed window: full membership in external-id space."""

    t: float
    ext: np.ndarray          # int64[k] external vertex ids, sorted
    cid: np.ndarray          # int64[k] persistent community id per vertex
    n_communities: int
    n_disconnected: int

    def membership(self, external: int) -> Optional[int]:
        i = int(np.searchsorted(self.ext, int(external)))
        if i < self.ext.size and int(self.ext[i]) == int(external):
            return int(self.cid[i])
        return None


@dataclasses.dataclass
class CommunityTimeline:
    """One persistent community's recorded trajectory."""

    cid: int
    graph_id: str
    born_t: float
    dead_t: Optional[float] = None
    parents: Tuple[int, ...] = ()
    origin: str = "birth"            # birth | split | seed
    # (t, size, weight) rows, newest last, capped by the store
    rows: Deque[Tuple[float, int, float]] = dataclasses.field(
        default_factory=deque)

    @property
    def alive(self) -> bool:
        return self.dead_t is None

    @property
    def last_size(self) -> int:
        return self.rows[-1][1] if self.rows else 0


class TimelineStore:
    def __init__(self, *, max_snapshots: int = 64, max_events: int = 4096,
                 max_rows: int = 256, max_communities: int = 4096):
        for name, v in (("max_snapshots", max_snapshots),
                        ("max_events", max_events),
                        ("max_rows", max_rows),
                        ("max_communities", max_communities)):
            if v < 1:
                raise ValueError(f"{name} must be >= 1, got {v}")
        self.max_snapshots = int(max_snapshots)
        self.max_events = int(max_events)
        self.max_rows = int(max_rows)
        self.max_communities = int(max_communities)
        self._snaps: Dict[str, Deque[Snapshot]] = {}
        self._times: Dict[str, List[float]] = {}    # mirror for bisect
        self._comms: "OrderedDict[int, CommunityTimeline]" = OrderedDict()
        self._events: Deque[LifecycleEvent] = deque(maxlen=self.max_events)
        self.n_snapshots = 0
        self.n_events = 0
        self.n_truncated_communities = 0

    # -- writes ------------------------------------------------------------
    def record_snapshot(self, graph_id: str, t: float,
                        members: Sequence[Tuple[int, Members]],
                        events: Sequence[LifecycleEvent], *,
                        n_disconnected: int = 0):
        """Append one window: ``members`` is (persistent id, member map)
        per community; ``events`` the matcher's lifecycle decisions."""
        ext_all, cid_all = [], []
        for cid, mem in members:
            ext_all.extend(mem.keys())
            cid_all.extend([cid] * len(mem))
        ext = np.asarray(ext_all, np.int64)
        cid = np.asarray(cid_all, np.int64)
        order = np.argsort(ext, kind="stable")
        snap = Snapshot(t=float(t), ext=ext[order], cid=cid[order],
                        n_communities=len(members),
                        n_disconnected=int(n_disconnected))
        dq = self._snaps.setdefault(
            graph_id, deque(maxlen=self.max_snapshots))
        dq.append(snap)
        self._times[graph_id] = [s.t for s in dq]
        self.n_snapshots += 1

        for cid_, mem in members:
            tl = self._comms.get(cid_)
            if tl is None:
                tl = self._new_timeline(cid_, graph_id, t)
            tl.rows.append((float(t), len(mem),
                            float(sum(mem.values()))))
            while len(tl.rows) > self.max_rows:
                tl.rows.popleft()
            self._comms.move_to_end(cid_)
        for ev in events:
            self._events.append(ev)
            self.n_events += 1
            tl = self._comms.get(ev.community)
            if ev.kind in ("birth", "split"):
                if tl is None:
                    tl = self._new_timeline(ev.community, graph_id, ev.t)
                tl.parents = ev.parents
                tl.origin = ev.kind
                tl.born_t = ev.t
            elif ev.kind == "death" and tl is not None:
                tl.dead_t = ev.t
        # cap resident community timelines (dead-first, then oldest)
        while len(self._comms) > self.max_communities:
            victim = None
            for k, v in self._comms.items():
                if not v.alive:
                    victim = k
                    break
            if victim is None:
                victim = next(iter(self._comms))
            del self._comms[victim]
            self.n_truncated_communities += 1

    def _new_timeline(self, cid: int, graph_id: str,
                      t: float) -> CommunityTimeline:
        tl = CommunityTimeline(cid=cid, graph_id=graph_id, born_t=float(t))
        self._comms[cid] = tl
        return tl

    # -- reads -------------------------------------------------------------
    def snapshot_at(self, graph_id: str,
                    t: Optional[float] = None) -> Optional[Snapshot]:
        """Latest snapshot with ``t_snap <= t`` (None = latest overall)."""
        dq = self._snaps.get(graph_id)
        if not dq:
            return None
        if t is None:
            return dq[-1]
        times = self._times.get(graph_id, [])
        i = bisect.bisect_right(times, float(t)) - 1
        return dq[i] if i >= 0 else None

    def membership_at(self, graph_id: str, external: int,
                      t: Optional[float] = None) -> Optional[int]:
        """Persistent community id of a vertex as of time ``t`` (None =
        now); None when the vertex is unknown at that time or the window
        fell off the retention horizon."""
        snap = self.snapshot_at(graph_id, t)
        return None if snap is None else snap.membership(external)

    def snapshots(self, graph_id: str) -> List[Snapshot]:
        return list(self._snaps.get(graph_id, ()))

    def timeline(self, community_id: int) -> Optional[CommunityTimeline]:
        return self._comms.get(int(community_id))

    def communities(self, graph_id: Optional[str] = None, *,
                    alive_only: bool = False) -> List[CommunityTimeline]:
        out = []
        for tl in self._comms.values():
            if graph_id is not None and tl.graph_id != graph_id:
                continue
            if alive_only and not tl.alive:
                continue
            out.append(tl)
        return out

    def lifecycle_events(self, graph_id: Optional[str] = None, *,
                         kind: Optional[str] = None
                         ) -> List[LifecycleEvent]:
        return [e for e in self._events
                if (graph_id is None or e.graph_id == graph_id)
                and (kind is None or e.kind == kind)]

    def drop_graph(self, graph_id: str) -> int:
        """Explicit retention control: forget a graph's snapshots,
        community rows and events.  This — not ResultStore eviction — is
        the ONLY way timeline history goes away besides the bounded
        deques rolling over."""
        n = len(self._snaps.pop(graph_id, ()))
        self._times.pop(graph_id, None)
        for cid in [c for c, tl in self._comms.items()
                    if tl.graph_id == graph_id]:
            del self._comms[cid]
        self._events = deque(
            (e for e in self._events if e.graph_id != graph_id),
            maxlen=self.max_events)
        return n
