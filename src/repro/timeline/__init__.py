"""Temporal community tracking over the dynamic-update service.

The dynamic core (PRs 3/5) answers "what are the communities now" after
edge/vertex churn; this package answers "what *happened* to them":

* :mod:`repro.timeline.idmap`   — stable **external vertex ids** over
  the core's order-preserving compaction remaps (and deferred
  tombstones), so clients address vertices by one id for life;
* :mod:`repro.timeline.matcher` — snapshot-to-snapshot community
  matching (weighted Jaccard on external-id member sets) assigning
  persistent community identities and emitting lifecycle events:
  birth, death, merge, split, continuation;
* :mod:`repro.timeline.store`   — bounded-memory timeline store:
  membership snapshots (``membership_at``), per-community rows
  (``timeline``), the lifecycle event log;
* :mod:`repro.timeline.tracker` — :class:`TimelineManager` (hangs off
  the ResultStore commit hook; one snapshot per commit), window
  translation from external-id event streams
  (:func:`translate_window`), and :class:`WindowedIngest`;
* :mod:`repro.timeline.checkpoint` — save/restore of timelines + warm
  store entries through :mod:`repro.checkpoint.store`.

Wired into the service by ``ServiceConfig(timeline_enabled=True)`` —
see the README "Temporal tracking" section for the event schema, window
semantics and the external-id contract.  The paper's zero-disconnected
invariant holds at every window boundary: each snapshot is produced by
the warm path's split pass, and the stream smoke asserts
``n_disconnected == 0`` on every one.
"""
from repro.timeline.checkpoint import (
    restore_service_checkpoint, save_service_checkpoint,
)
from repro.timeline.idmap import ExternalIdMap, compose_batch_maps
from repro.timeline.matcher import (
    LIFECYCLE_KINDS, LifecycleEvent, match_snapshots, weighted_jaccard,
)
from repro.timeline.store import CommunityTimeline, Snapshot, TimelineStore
from repro.timeline.tracker import (
    TimelineConfig, TimelineManager, WindowedIngest, translate_window,
)

__all__ = [
    "CommunityTimeline",
    "ExternalIdMap",
    "LIFECYCLE_KINDS",
    "LifecycleEvent",
    "Snapshot",
    "TimelineConfig",
    "TimelineManager",
    "TimelineStore",
    "WindowedIngest",
    "compose_batch_maps",
    "match_snapshots",
    "restore_service_checkpoint",
    "save_service_checkpoint",
    "translate_window",
    "weighted_jaccard",
]
