"""Stable external vertex ids over internal compactions.

The dynamic core compacts removed vertices away (PR 5): a surviving
internal id shifts down by the number of removed ids below it, every
batch (the *compaction contract* of :func:`repro.core.dynamic.
apply_vertex_updates`).  That keeps device shapes dense but makes raw
internal ids useless as long-lived names — after two removals "vertex 7"
is a different vertex.  :class:`ExternalIdMap` is the id-map layer over
the contract's ``UpdatePlan.id_map`` remaps: every vertex gets an
**external id on first sight and keeps it for life**, across arbitrarily
many compactions, deferred-compaction tombstones, re-bucketing rebuilds
and checkpoint restores.  All timeline state (member sets, snapshots,
``membership_at`` answers) lives in external-id space.

The contract:

* externals are assigned from one monotone counter, never reused;
* ``apply(id_map, n_new)`` folds one committed remap: survivors carry
  their external through ``id_map``; internal slots in ``[0, n_new)``
  not claimed by a survivor (vertex additions) get fresh externals in
  increasing internal-id order — exactly the order the core assigns
  added ids, so client and service agree without a handshake;
* ``retire_internal(ids)`` handles deferred compaction: the external
  retires at removal time even though the internal slot lingers as a
  tombstone until the store pays for the remap.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class ExternalIdMap:
    """Bidirectional internal<->external vertex id map (host-side).

    Not thread-safe on its own — the owning
    :class:`repro.timeline.tracker.TimelineManager` serializes access.
    """

    def __init__(self, n: int = 0, *, start: int = 0):
        self._ext = np.arange(start, start + int(n), dtype=np.int64)
        self._int: Dict[int, int] = {int(e): i
                                     for i, e in enumerate(self._ext)}
        self._next = start + int(n)
        self._retired: set = set()

    # -- introspection -----------------------------------------------------
    @property
    def n_slots(self) -> int:
        """Internal id range covered (including deferred tombstones)."""
        return int(self._ext.size)

    @property
    def n_live(self) -> int:
        return len(self._int)

    @property
    def next_external(self) -> int:
        return self._next

    def externals(self) -> np.ndarray:
        """int64[n_slots]: external id per internal slot, -1 at deferred
        tombstones."""
        return self._ext.copy()

    def external_of(self, internal: int) -> int:
        e = int(self._ext[int(internal)])
        if e < 0:
            raise KeyError(f"internal id {internal} is a retired tombstone")
        return e

    def internal_of(self, external: int) -> Optional[int]:
        """Current internal slot of an external id; None once retired."""
        return self._int.get(int(external))

    def __contains__(self, external: int) -> bool:
        return int(external) in self._int

    def is_retired(self, external: int) -> bool:
        return int(external) in self._retired

    # -- mutation ----------------------------------------------------------
    def apply(self, id_map: Optional[np.ndarray], n_new: int, *,
              fresh_ids: Optional[Sequence[int]] = None
              ) -> Tuple[List[int], List[int]]:
        """Fold one committed vertex remap.

        ``id_map``: old internal -> new internal over at least the old
        slot range, ``-1`` at removed ids (``UpdatePlan.id_map``); None
        means identity over the surviving prefix (pure growth, or an
        edges-only commit).  ``n_new``: the post-commit ``n_nodes``.

        ``fresh_ids``: externally-chosen ids for the newly claimed
        internal slots (in claim order) — how the windowed ingest layer
        binds the client's names for added vertices.  Must match the
        fresh-slot count exactly and not collide with live or retired
        externals; otherwise the binding is rejected wholesale and the
        slots mint from the internal counter (callers can detect the
        fallback by comparing the returned ``fresh`` list).

        Returns ``(fresh, retired)`` external ids: ``fresh`` for newly
        claimed internal slots (in increasing internal-id order) and
        ``retired`` for externals whose vertex was removed by this remap
        (excluding tombstones already retired via
        :meth:`retire_internal`).
        """
        n_new = int(n_new)
        old = self._ext
        ext = np.full(n_new, -1, np.int64)
        # deferred-tombstone slots (-1 in old) that survive this remap are
        # NOT fresh: they stay dead until a flush drops them.  Without
        # this, a pure-growth commit while tombstones linger would bind
        # (or mint) new externals into dead slots.
        tomb = np.empty(0, np.int64)
        if id_map is None:
            k = min(old.size, n_new)
            ext[:k] = old[:k]
            tomb = np.flatnonzero(old[:k] < 0)
        elif old.size:
            dest = np.asarray(id_map, np.int64)[:old.size]
            ok = (dest >= 0) & (dest < n_new) & (old >= 0)
            ext[dest[ok]] = old[ok]
            tomb = dest[(dest >= 0) & (dest < n_new) & (old < 0)]
        survivors = set(ext[ext >= 0].tolist())
        retired = sorted(set(old[old >= 0].tolist()) - survivors)
        self._retired.update(retired)
        fresh_mask = ext < 0
        fresh_mask[tomb] = False
        fresh_slots = np.flatnonzero(fresh_mask)
        fresh: List[int] = []
        if fresh_ids is not None and len(fresh_ids) == fresh_slots.size:
            cand = [int(e) for e in fresh_ids]
            if (len(set(cand)) == len(cand)
                    and not any(e in survivors or e in self._retired
                                for e in cand)):
                fresh = cand
        if not fresh and fresh_slots.size:
            fresh = list(range(self._next, self._next + fresh_slots.size))
        if fresh:
            ext[fresh_slots] = fresh
            self._next = max(self._next, max(fresh) + 1)
        self._ext = ext
        self._int = {int(e): i for i, e in enumerate(ext) if e >= 0}
        return fresh, retired

    def retire_internal(self, internal_ids: Sequence[int]) -> List[int]:
        """Deferred removal: retire the externals NOW while the internal
        slots linger as tombstones (``-1`` in :meth:`externals`) until a
        later compaction's :meth:`apply` drops them."""
        retired = []
        for i in internal_ids:
            i = int(i)
            e = int(self._ext[i])
            if e < 0:
                continue
            self._ext[i] = -1
            self._int.pop(e, None)
            self._retired.add(e)
            retired.append(e)
        return retired

    # -- checkpointing -----------------------------------------------------
    def state(self) -> Tuple[np.ndarray, int, np.ndarray]:
        return (self._ext.copy(), self._next,
                np.asarray(sorted(self._retired), np.int64))

    @classmethod
    def from_state(cls, ext: np.ndarray, next_external: int,
                   retired=()) -> "ExternalIdMap":
        m = cls(0)
        m._ext = np.asarray(ext, np.int64).copy()
        m._int = {int(e): i for i, e in enumerate(m._ext) if e >= 0}
        m._next = int(next_external)
        m._retired = set(int(e) for e in np.asarray(retired).ravel())
        return m


def compose_batch_maps(n0: int, batches) -> Tuple[np.ndarray, int]:
    """Compose the compaction contract across folded update batches.

    Mirrors :func:`repro.core.dynamic.rebuild_with_vertex_ops` /
    ``prepare_update_seq`` semantics without touching a graph: per batch,
    removals drop their ids (survivors shift down, order-preserving),
    then ``add`` claims the next ids.  Returns ``(id_map, n_final)``
    where ``id_map`` is int64[n0] old->final internal (-1 removed) —
    what :meth:`ExternalIdMap.apply` needs to track a re-bucketing
    rebuild (:func:`repro.service.frontend._graph_with_updates`), which
    replays exactly these semantics.
    """
    from repro.core.dynamic import as_update

    cur = np.arange(int(n0), dtype=np.int64)
    n = int(n0)
    for upd in batches:
        upd = as_update(upd)
        rem = np.asarray(upd.remove, np.int64).ravel()
        if rem.size:
            if rem.size and (int(rem.min()) < 0 or int(rem.max()) >= n):
                raise ValueError(
                    f"remove ids must be in [0, {n}); got "
                    f"[{int(rem.min())}, {int(rem.max())}]")
            alive = np.ones(n, bool)
            alive[rem] = False
            shift = np.cumsum(alive) - 1          # new id per old alive id
            live = cur >= 0
            src = np.clip(cur, 0, n - 1)
            cur = np.where(live & alive[src], shift[src], -1)
            n -= rem.size
        n += int(upd.add)
    return cur, n
