"""Snapshot-to-snapshot community matching + lifecycle events.

Louvain's community *labels* are meaningless across runs — renumbering
permutes them freely even when the partition barely moved.  To build
timelines the service needs persistent community *identities*: given the
previous snapshot's communities (persistent id -> weighted member set,
in external vertex ids) and the new snapshot's communities (anonymous
member sets), decide which new community continues which old one and
what happened to the rest.

The matcher scores every overlapping (prev, new) pair with **weighted
Jaccard** on member sets — ``J(A, B) = w(A ∩ B) / w(A ∪ B)`` with
per-vertex weights (1.0 by default, vertex degree under
``weight_by_degree``) — and assigns greedily in deterministic order
(overlap desc, then prev id asc, then new index asc):

* the best unclaimed pair at or above ``jaccard_min`` is a
  **continuation**: the new community inherits the persistent id;
* a new community whose best qualifying overlap points at an
  already-claimed ancestor is a **split** child (fresh id, ancestor
  recorded as parent);
* a previous community whose best qualifying overlap points at an
  already-claimed heir **merged** into it (recorded as a parent on the
  heir's merge event);
* no qualifying overlap at all: **birth** (new) / **death** (prev).

One window may carry several of these at once (the simultaneous
merge+split case is covered by tests): the greedy pass resolves them
consistently because every decision consumes exactly one side of a
pair.  Ties are impossible to break "wrong" — equal-overlap candidates
order by the smaller persistent id, so reruns are bit-reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

# a community's members: external vertex id -> weight
Members = Dict[int, float]

LIFECYCLE_KINDS = ("birth", "death", "merge", "split", "continuation")


@dataclasses.dataclass(frozen=True)
class LifecycleEvent:
    """One community lifecycle transition at a window boundary.

    ``community`` is the persistent id the event is about: the surviving
    heir for merge, the new child for split/birth, the vanished id for
    death, the carried id for continuation.  ``parents`` names the other
    side: absorbed ids (merge) or the ancestor (split).  ``overlap`` is
    the weighted Jaccard that justified the decision (0 for
    birth/death).
    """

    kind: str
    t: float
    graph_id: str
    community: int
    parents: Tuple[int, ...] = ()
    overlap: float = 0.0
    size: int = 0

    def __post_init__(self):
        if self.kind not in LIFECYCLE_KINDS:
            raise ValueError(f"unknown lifecycle kind {self.kind!r}")


def weighted_jaccard(a: Members, b: Members) -> float:
    """w(A ∩ B) / w(A ∪ B) over external-id member sets; 0 when both
    empty.  Intersection takes min weight per vertex, union max — the
    standard weighted-Jaccard extension (equal weights reduce it to
    |A∩B| / |A∪B|)."""
    if not a or not b:
        return 0.0
    small, big = (a, b) if len(a) <= len(b) else (b, a)
    inter = 0.0
    for v, w in small.items():
        wb = big.get(v)
        if wb is not None:
            inter += min(w, wb)
    if inter == 0.0:
        return 0.0
    union = sum(a.values()) + sum(b.values())
    for v, w in small.items():
        wb = big.get(v)
        if wb is not None:
            union -= min(w, wb)
    return inter / union if union > 0 else 0.0


def match_snapshots(prev: Dict[int, Members], new: Sequence[Members], *,
                    t: float, graph_id: str, jaccard_min: float = 0.1,
                    next_id: Callable[[], int],
                    on_overlap: Callable[[float], None] = None,
                    ) -> Tuple[List[int], List[LifecycleEvent]]:
    """Assign persistent ids to ``new`` communities and emit lifecycle
    events vs ``prev``.

    Returns ``(assigned, events)`` where ``assigned[i]`` is the
    persistent id of ``new[i]`` and ``events`` lists every transition
    (continuations included) in deterministic order.  ``next_id`` mints
    fresh persistent ids (births and split children).  ``on_overlap``
    optionally observes every qualifying pair's Jaccard (telemetry
    histogram).
    """
    # score all overlapping pairs via an inverted vertex index: O(sum of
    # member-list sizes), not |prev| x |new|
    owner: Dict[int, List[int]] = {}
    for i, members in enumerate(new):
        for v in members:
            owner.setdefault(v, []).append(i)
    pair_keys = set()
    for pid, members in prev.items():
        for v in members:
            for i in owner.get(v, ()):
                pair_keys.add((pid, i))
    scored = []
    for pid, i in pair_keys:
        j = weighted_jaccard(prev[pid], new[i])
        if j >= jaccard_min:
            if on_overlap is not None:
                on_overlap(j)
            scored.append((j, pid, i))
    scored.sort(key=lambda s: (-s[0], s[1], s[2]))

    assigned: List[int] = [-1] * len(new)
    claimed_prev: Dict[int, int] = {}     # prev pid -> heir new index
    cont_overlap: Dict[int, float] = {}   # new index -> inherited overlap
    # pass 1: continuations (best unclaimed pair on both sides)
    for j, pid, i in scored:
        if assigned[i] < 0 and pid not in claimed_prev:
            assigned[i] = pid
            claimed_prev[pid] = i
            cont_overlap[i] = j
    # pass 2: splits — unassigned new with a qualifying (claimed) ancestor
    split_parent: Dict[int, Tuple[int, float]] = {}
    for j, pid, i in scored:
        if assigned[i] < 0 and i not in split_parent:
            split_parent[i] = (pid, j)
    for i in range(len(new)):
        if assigned[i] < 0 and i in split_parent:
            assigned[i] = next_id()
    # pass 3: merges — unclaimed prev with a qualifying (assigned) heir
    merged_into: Dict[int, List[Tuple[int, float]]] = {}  # new idx -> prev
    merge_best: Dict[int, float] = {}
    for j, pid, i in scored:
        if pid not in claimed_prev and pid not in merge_best:
            merged_into.setdefault(i, []).append((pid, j))
            merge_best[pid] = j
    # pass 4: births
    for i in range(len(new)):
        if assigned[i] < 0:
            assigned[i] = next_id()

    events: List[LifecycleEvent] = []
    for i in range(len(new)):
        size = len(new[i])
        if i in cont_overlap:
            parents = merged_into.get(i)
            if parents:
                events.append(LifecycleEvent(
                    "merge", t, graph_id, assigned[i],
                    parents=tuple(p for p, _ in parents),
                    overlap=max(j for _, j in parents), size=size))
            else:
                events.append(LifecycleEvent(
                    "continuation", t, graph_id, assigned[i],
                    overlap=cont_overlap[i], size=size))
        elif i in split_parent:
            pid, j = split_parent[i]
            events.append(LifecycleEvent(
                "split", t, graph_id, assigned[i], parents=(pid,),
                overlap=j, size=size))
            parents = merged_into.get(i)
            if parents:
                # a split child can absorb an unclaimed community in the
                # same window (the simultaneous merge+split case)
                events.append(LifecycleEvent(
                    "merge", t, graph_id, assigned[i],
                    parents=tuple(p for p, _ in parents),
                    overlap=max(jj for _, jj in parents), size=size))
        else:
            events.append(LifecycleEvent(
                "birth", t, graph_id, assigned[i], size=size))
    for pid in sorted(prev):
        if pid not in claimed_prev and pid not in merge_best:
            events.append(LifecycleEvent(
                "death", t, graph_id, pid, size=0))
    return assigned, events
