"""GatedGCN (Bresson & Laurent via Dwivedi et al., arXiv:2003.00982).

Edge-featured MPNN with gated aggregation:
    e'_ij = A h_i + B h_j + C e_ij ;  sigma_ij = sigmoid(e'_ij)
    h'_i  = h_i + ReLU(BN(U h_i + sum_j sigma_ij (.) V h_j / (sum sigma + eps)))
(benchmark configuration: 16 layers, 70 hidden, residual, no BN stats here —
layernorm stands in, which the benchmarking-gnns code also supports).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_in: int = 32
    d_hidden: int = 70
    n_classes: int = 6


def init_gatedgcn(key, cfg: GatedGCNConfig):
    d = cfg.d_hidden
    keys = jax.random.split(key, 3 + cfg.n_layers)
    layers = []
    for li in range(cfg.n_layers):
        k = jax.random.split(keys[li], 5)
        layers.append(dict(
            A=common.linear(k[0], d, d), B=common.linear(k[1], d, d),
            C=common.linear(k[2], d, d), U=common.linear(k[3], d, d),
            V=common.linear(k[4], d, d),
            ln_h=jnp.ones((d,), jnp.float32),
            ln_e=jnp.ones((d,), jnp.float32),
        ))
    return dict(
        embed_h=common.linear(keys[-3], cfg.d_in, d),
        embed_e=common.linear(keys[-2], 1, d),
        head=common.linear(keys[-1], d, cfg.n_classes),
        layers=layers,
    )


def param_logical_axes(cfg: GatedGCNConfig):
    lx = dict(A=("fsdp", "feat"), B=("fsdp", "feat"), C=("fsdp", "feat"),
              U=("fsdp", "feat"), V=("fsdp", "feat"),
              ln_h=(None,), ln_e=(None,))
    return dict(
        embed_h=("fsdp", "feat"), embed_e=(None, "feat"),
        head=("feat", None), layers=[lx] * cfg.n_layers,
    )


def _ln(x, g, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g


def gatedgcn_forward(params, x, src, dst, w, cfg: GatedGCNConfig,
                     edge_mask=None):
    """x: [nv, d_in]; w: f32[M] edge weights used as scalar edge features."""
    nv = x.shape[0]
    if edge_mask is None:
        edge_mask = src < (nv - 1)
    h = x @ params["embed_h"]
    e = w[:, None] @ params["embed_e"]                  # [M, D]
    for lp in params["layers"]:
        eh = h[src] @ lp["A"] + h[dst] @ lp["B"] + e @ lp["C"]
        gate = jax.nn.sigmoid(eh)
        gate = jnp.where(edge_mask[:, None], gate, 0.0)
        num = common.scatter_sum(gate * (h[src] @ lp["V"]), dst, nv)
        den = common.scatter_sum(gate, dst, nv)
        agg = h @ lp["U"] + num / (den + 1e-6)
        h = h + jax.nn.relu(_ln(agg, lp["ln_h"]))
        e = e + jax.nn.relu(_ln(eh, lp["ln_e"]))
    return h @ params["head"]
