"""GCN (Kipf & Welling, arXiv:1609.02907): Ahat X W via edge scatter.

``Ahat = D^-1/2 (A + I) D^-1/2`` is applied as per-edge coefficients plus a
self-term — no sparse matrix is materialized.  ``aggregator='mean'`` (the
gcn-cora config) swaps symmetric normalization for mean aggregation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.gnn import common


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    norm: str = "sym"          # sym | mean
    dropout: float = 0.0


def init_gcn(key, cfg: GCNConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, len(dims))
    return dict(
        w=[common.linear(keys[i], dims[i], dims[i + 1]) for i in range(len(dims) - 1)],
        b=[jnp.zeros((dims[i + 1],), jnp.float32) for i in range(len(dims) - 1)],
    )


def param_logical_axes(cfg: GCNConfig):
    n = cfg.n_layers
    return dict(w=[("fsdp", "feat")] * n, b=[(None,)] * n)


def gcn_forward(params, x, src, dst, cfg: GCNConfig, edge_mask=None):
    """x: [nv, d_in] node features (ghost row zero) -> logits [nv, C]."""
    nv = x.shape[0]
    if edge_mask is None:
        edge_mask = src < (nv - 1)
    if cfg.norm == "sym":
        coeff = common.sym_norm_coeff(src, dst, nv, edge_mask)
        self_c = 1.0 / (common.degree(src, nv, edge_mask) + 1.0)
    else:
        deg = jnp.maximum(common.degree(dst, nv, edge_mask), 1.0)
        coeff = 1.0 / deg[dst]
        self_c = jnp.zeros((nv,))  # mean over in-neighbors only
    coeff = jnp.where(edge_mask, coeff, 0.0)

    h = x
    for li, (w, b) in enumerate(zip(params["w"], params["b"])):
        h = h @ w + b
        msg = h[src] * coeff[:, None]
        agg = common.scatter_sum(msg, dst, nv)
        if cfg.norm == "sym":
            agg = agg + h * self_c[:, None]
        h = agg
        if li < len(params["w"]) - 1:
            h = jax.nn.relu(h)
    return h
