"""GAT (Velickovic et al., arXiv:1710.10903): SDDMM edge scores ->
segment-softmax -> weighted scatter.  gat-cora: 2 layers, 8 hidden, 8 heads.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    negative_slope: float = 0.2


def init_gat(key, cfg: GATConfig):
    layers = []
    d_in = cfg.d_in
    for li in range(cfg.n_layers):
        last = li == cfg.n_layers - 1
        h = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append(dict(
            w=common.linear(k1, d_in, h * d_out),
            a_src=jax.random.normal(k2, (h, d_out), jnp.float32) * 0.1,
            a_dst=jax.random.normal(k3, (h, d_out), jnp.float32) * 0.1,
        ))
        d_in = h * d_out if not last else d_out
    return dict(layers=layers)


def param_logical_axes(cfg: GATConfig):
    return dict(layers=[
        dict(w=("fsdp", "heads"), a_src=("heads", None), a_dst=("heads", None))
        for _ in range(cfg.n_layers)
    ])


def gat_forward(params, x, src, dst, cfg: GATConfig, edge_mask=None):
    nv = x.shape[0]
    if edge_mask is None:
        edge_mask = src < (nv - 1)
    h = x
    n_layers = len(params["layers"])
    for li, lp in enumerate(params["layers"]):
        last = li == n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = lp["w"].shape[1] // heads
        z = (h @ lp["w"]).reshape(nv, heads, d_out)
        e_src = jnp.einsum("nhd,hd->nh", z, lp["a_src"])
        e_dst = jnp.einsum("nhd,hd->nh", z, lp["a_dst"])
        scores = jax.nn.leaky_relu(
            e_src[src] + e_dst[dst], cfg.negative_slope
        )                                              # [M, H]
        alpha = common.edge_softmax(scores, dst, nv, edge_mask)
        msg = z[src] * alpha[..., None]                # [M, H, D]
        agg = common.scatter_sum(msg, dst, nv)         # [nv, H, D]
        if last:
            h = agg[:, 0]
        else:
            h = jax.nn.elu(agg.reshape(nv, heads * d_out))
    return h
