"""GNN zoo: GCN, GAT, GatedGCN (segment-op message passing) and NequIP
(E(3)-equivariant tensor-product message passing).

All message passing is built on ``jax.ops.segment_sum`` / ``segment_max``
over padded edge lists — the same kernel regime as the paper's Louvain
phases (JAX has no CSR SpMM; the edge-scatter formulation IS the system,
per the assignment notes).
"""
from repro.models.gnn.gcn import GCNConfig, init_gcn, gcn_forward
from repro.models.gnn.gat import GATConfig, init_gat, gat_forward
from repro.models.gnn.gatedgcn import GatedGCNConfig, init_gatedgcn, gatedgcn_forward
from repro.models.gnn.nequip import NequIPConfig, init_nequip, nequip_forward

__all__ = [
    "GCNConfig", "init_gcn", "gcn_forward",
    "GATConfig", "init_gat", "gat_forward",
    "GatedGCNConfig", "init_gatedgcn", "gatedgcn_forward",
    "NequIPConfig", "init_nequip", "nequip_forward",
]
