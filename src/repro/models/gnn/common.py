"""Shared GNN message-passing primitives over padded edge lists.

Edge convention matches :mod:`repro.graph.container`: directed COO with a
ghost vertex absorbing padding; per-edge masks are implied by ``src < ghost``
and zero weights.  Features are [nv, D] with the ghost row zeroed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scatter_sum(values, index, nv):
    return jax.ops.segment_sum(values, index, num_segments=nv)


def scatter_max(values, index, nv, fill=-jnp.inf):
    out = jax.ops.segment_max(values, index, num_segments=nv)
    return jnp.where(jnp.isfinite(out), out, fill)


def degree(src, nv, edge_mask=None):
    ones = jnp.ones(src.shape, jnp.float32)
    if edge_mask is not None:
        ones = jnp.where(edge_mask, ones, 0.0)
    return jax.ops.segment_sum(ones, src, num_segments=nv)


def sym_norm_coeff(src, dst, nv, edge_mask=None):
    """GCN symmetric normalization 1/sqrt((d_u+1)(d_v+1)) per edge."""
    d = degree(src, nv, edge_mask) + 1.0
    return jax.lax.rsqrt(d[src]) * jax.lax.rsqrt(d[dst])


def edge_softmax(scores, dst, nv, edge_mask):
    """Softmax of per-edge scores grouped by destination vertex.

    scores: [M] or [M, H]; edge_mask: bool[M].
    """
    mask = edge_mask if scores.ndim == 1 else edge_mask[:, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    mx = scatter_max(scores, dst, nv, fill=0.0)
    ex = jnp.where(mask, jnp.exp(scores - mx[dst]), 0.0)
    denom = scatter_sum(ex, dst, nv)
    return ex / jnp.maximum(denom[dst], 1e-9)


def linear(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
