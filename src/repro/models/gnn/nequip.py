"""NequIP (Batzner et al., arXiv:2101.03164): E(3)-equivariant interatomic
potential with channel-wise ("uvu") Clebsch-Gordan tensor-product messages.

Node state: one feature block per irrep degree l in {0..l_max}:
``h[l]: [nv, C, 2l+1]``.  Message for path (l1, l2 -> l3):

    m3[e] = R_path(|r_e|) * einsum('ci,j,ijk->ck', h[l1][src_e], sh_l2(r_e), CG)

summed over paths into each l3, scatter-summed over edges, then mixed by a
per-l self-interaction linear layer and a gate nonlinearity (scalars gate
the norms of l > 0 blocks).  Radial weights come from a Bessel-RBF + cutoff
envelope MLP, one output per (path, channel) — NequIP's structure, with the
assigned config: 5 layers, 32 channels, l_max 2, 8 RBFs, cutoff 5.0.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import common
from repro.models.gnn.irreps import admissible_paths, clebsch_gordan, sh


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32           # channels per irrep degree
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    radial_hidden: int = 16


def _paths(cfg):
    return admissible_paths(cfg.l_max)


def init_nequip(key, cfg: NequIPConfig):
    C = cfg.d_hidden
    paths = _paths(cfg)
    keys = jax.random.split(key, 3 + cfg.n_layers)
    layers = []
    for li in range(cfg.n_layers):
        k = jax.random.split(keys[li], 4 + len(paths) + cfg.l_max + 1)
        radial = dict(
            w1=common.linear(k[0], cfg.n_rbf, cfg.radial_hidden),
            w2=common.linear(k[1], cfg.radial_hidden, len(paths) * C),
        )
        self_int = {
            str(l): common.linear(k[2 + l], C, C)
            for l in range(cfg.l_max + 1)
        }
        gates = common.linear(k[3 + cfg.l_max], C, cfg.l_max * C)
        layers.append(dict(radial=radial, self_int=self_int, gates=gates))
    return dict(
        species_embed=jax.random.normal(keys[-2], (cfg.n_species, C)) * 0.5,
        layers=layers,
        readout=common.linear(keys[-1], C, 1),
    )


def param_logical_axes(cfg: NequIPConfig):
    paths = _paths(cfg)
    layer = dict(
        radial=dict(w1=(None, None), w2=(None, "feat")),
        self_int={str(l): ("feat", None) for l in range(cfg.l_max + 1)},
        gates=("feat", None),
    )
    return dict(
        species_embed=(None, "feat"),
        layers=[layer] * cfg.n_layers,
        readout=("feat", None),
    )


def bessel_rbf(r, n_rbf, cutoff):
    """Bessel radial basis with smooth polynomial cutoff envelope."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(
        n[None, :] * jnp.pi * r[:, None] / cutoff
    ) / r[:, None]
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5   # p=3 polynomial
    return basis * env[:, None]


def nequip_forward(params, species, pos, src, dst, cfg: NequIPConfig,
                   edge_mask=None):
    """species: int32[nv], pos: f32[nv, 3] -> per-node scalar energy [nv].

    Padded edges must point at the ghost vertex; ghost rows contribute 0.
    """
    nv = species.shape[0]
    if edge_mask is None:
        edge_mask = src < (nv - 1)
    C = cfg.d_hidden
    paths = _paths(cfg)
    cg = {p: jnp.asarray(clebsch_gordan(*p), jnp.float32) for p in paths}

    rvec = pos[dst] - pos[src]
    r = jnp.sqrt(jnp.sum(rvec * rvec, axis=-1) + 1e-12)
    rhat = rvec / r[:, None]
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)
    rbf = jnp.where(edge_mask[:, None], rbf, 0.0)
    edge_sh = {l: sh(rhat, l) for l in range(cfg.l_max + 1)}

    h = {0: params["species_embed"][species][:, :, None]}
    for l in range(1, cfg.l_max + 1):
        h[l] = jnp.zeros((nv, C, 2 * l + 1), jnp.float32)

    for lp in params["layers"]:
        rw = jax.nn.silu(rbf @ lp["radial"]["w1"]) @ lp["radial"]["w2"]
        rw = rw.reshape(-1, len(paths), C)              # [M, P, C]
        msg = {l: 0.0 for l in range(cfg.l_max + 1)}
        for pi, (l1, l2, l3) in enumerate(paths):
            t = jnp.einsum(
                "eci,ej,ijk->eck", h[l1][src], edge_sh[l2], cg[(l1, l2, l3)]
            )
            msg[l3] = msg[l3] + t * rw[:, pi, :, None]
        agg = {l: common.scatter_sum(
            jnp.where(edge_mask[:, None, None], msg[l], 0.0), dst, nv)
            for l in msg}
        # self-interaction + residual
        new_h = {}
        for l in range(cfg.l_max + 1):
            mixed = jnp.einsum("ncm,cd->ndm", agg[l], lp["self_int"][str(l)])
            new_h[l] = h[l] + mixed
        # gate nonlinearity: scalars pass through silu and gate higher l
        scalars = new_h[0][:, :, 0]
        gates = jax.nn.sigmoid(scalars @ lp["gates"]).reshape(nv, cfg.l_max, C)
        h = {0: jax.nn.silu(scalars)[:, :, None]}
        for l in range(1, cfg.l_max + 1):
            h[l] = new_h[l] * gates[:, l - 1, :, None]

    energy = (h[0][:, :, 0] @ params["readout"])[:, 0]
    return energy
