"""Minimal real-basis SO(3) irrep machinery for NequIP (l_max <= 2).

Instead of porting Wigner/Racah formulas (and their basis-convention traps),
the Clebsch-Gordan tensors are computed **numerically** at import:

1. Real spherical-harmonic bases are *defined* by the closed-form
   polynomials in :func:`sh` (any spanning basis works — consistency is all
   that matters because step 2 uses the same basis).
2. The Wigner matrix ``D_l(R)`` for a sample rotation is recovered by
   least-squares from ``sh_l(R x) = D_l(R) sh_l(x)`` over random points.
3. The CG tensor for a path (l1, l2 -> l3) is the null space of the
   invariance constraints ``(D1 (x) D2 (x) D3) vec(T) = vec(T)`` stacked for
   several random rotations — dimension 1 for every admissible triple, so T
   is unique up to sign/scale (normalized to unit Frobenius norm).

This is exact to numerical precision and self-validating: an inadmissible
triple yields an empty null space (asserted).  Equivariance of the resulting
tensor product is property-tested in tests/test_nequip.py.
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp


def sh_np(x: np.ndarray, l: int) -> np.ndarray:
    """Real spherical-harmonic basis (unnormalized polynomials), x: [..., 3]."""
    X, Y, Z = x[..., 0], x[..., 1], x[..., 2]
    if l == 0:
        return np.ones(x.shape[:-1] + (1,), x.dtype)
    if l == 1:
        return np.stack([X, Y, Z], axis=-1)
    if l == 2:
        r2 = X * X + Y * Y + Z * Z
        return np.stack(
            [X * Y, Y * Z, (3 * Z * Z - r2) / (2 * np.sqrt(3.0)), X * Z,
             (X * X - Y * Y) / 2.0],
            axis=-1,
        ) * np.sqrt(3.0)
    raise NotImplementedError(l)


def sh(x, l: int):
    """jnp version of :func:`sh_np` (x: [..., 3])."""
    X, Y, Z = x[..., 0], x[..., 1], x[..., 2]
    if l == 0:
        return jnp.ones(x.shape[:-1] + (1,), x.dtype)
    if l == 1:
        return jnp.stack([X, Y, Z], axis=-1)
    if l == 2:
        r2 = X * X + Y * Y + Z * Z
        return jnp.stack(
            [X * Y, Y * Z, (3 * Z * Z - r2) / (2 * jnp.sqrt(3.0)), X * Z,
             (X * X - Y * Y) / 2.0],
            axis=-1,
        ) * jnp.sqrt(3.0)
    raise NotImplementedError(l)


def _rotation(rng) -> np.ndarray:
    """Random rotation matrix via QR of a Gaussian."""
    q, r = np.linalg.qr(rng.normal(size=(3, 3)))
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q


def wigner_d(R: np.ndarray, l: int) -> np.ndarray:
    """D_l(R) with sh_l(R x) = D_l(R) sh_l(x), by least squares."""
    rng = np.random.default_rng(12345 + l)
    pts = rng.normal(size=(64, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    A = sh_np(pts, l)                 # [K, 2l+1]
    B = sh_np(pts @ R.T, l)           # [K, 2l+1]
    D, *_ = np.linalg.lstsq(A, B, rcond=None)
    return D.T                        # B^T = D @ A^T


@functools.lru_cache(maxsize=None)
def clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis CG tensor [2l1+1, 2l2+1, 2l3+1], unit Frobenius norm."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        raise ValueError(f"inadmissible path {(l1, l2, l3)}")
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    rng = np.random.default_rng(0)
    rows = []
    eye = np.eye(d1 * d2 * d3)
    for _ in range(3):
        R = _rotation(rng)
        D1, D2, D3 = (wigner_d(R, l) for l in (l1, l2, l3))
        M = np.einsum("ab,cd,ef->acebdf", D1, D2, D3).reshape(
            d1 * d2 * d3, d1 * d2 * d3
        )
        rows.append(M - eye)
    A = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(A)
    null_dim = int(np.sum(s < 1e-8 * max(float(s[0]), 1.0)))
    # trailing rows of vt span the null space
    assert null_dim >= 1, f"no invariant tensor for {(l1, l2, l3)}: {s[-3:]}"
    T = vt[-1].reshape(d1, d2, d3)
    # parity within real polynomials also forbids odd l1+l2+l3 triples of
    # these bases when they'd be parity-inconsistent; the SVD finds the
    # invariant subspace regardless — normalize and fix an arbitrary sign.
    T = T / np.linalg.norm(T)
    flat = T.ravel()
    lead = flat[np.argmax(np.abs(flat) > 1e-9)]
    if lead < 0:
        T = -T
    return T


def admissible_paths(l_max: int):
    """All (l1, l2, l3) with every l <= l_max, |l1-l2| <= l3 <= l1+l2, and a
    nonempty invariant space in the real polynomial bases (parity-allowed:
    l1 + l2 + l3 even)."""
    paths = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if abs(l1 - l2) <= l3 <= l1 + l2 and (l1 + l2 + l3) % 2 == 0:
                    paths.append((l1, l2, l3))
    return paths
