"""BST — Behavior Sequence Transformer (Alibaba, arXiv:1905.06874).

CTR model: the user's behavior sequence (seq_len=20 item ids) plus the
target item are embedded (huge sparse tables — the hot path), passed through
one transformer block (8 heads), flattened, concatenated with user/context
"other features" embeddings, and scored by a 1024-512-256 MLP.

JAX has no EmbeddingBag; multi-hot user features use the canonical
``jnp.take`` + ``jax.ops.segment_sum`` formulation (:func:`embedding_bag`),
which shards row-wise over the 'model' mesh axis (table rows are the
dominant bytes; lookups become all-to-all-free gathers on the owning shard
under SPMD).

``bst_score_candidates`` is the ``retrieval_cand`` path: one user scored
against N candidates — the behavior-sequence encoding is computed once and
broadcast; only the target-position attention row + MLP run per candidate
(batched dot, not a loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    item_vocab: int = 4_000_000
    user_vocab: int = 2_000_000
    n_user_fields: int = 8          # multi-hot user profile fields
    user_field_vocab: int = 100_000
    embed_dim: int = 32
    seq_len: int = 20               # behavior sequence length (excl. target)
    n_blocks: int = 1
    n_heads: int = 8
    d_ff: int = 64
    mlp: tuple = (1024, 512, 256)
    dropout: float = 0.0


def init_bst(key, cfg: BSTConfig):
    d = cfg.embed_dim
    k = jax.random.split(key, 10 + len(cfg.mlp))
    seq_total = cfg.seq_len + 1
    flat = seq_total * d + d + cfg.n_user_fields * d
    mlp_dims = [flat] + list(cfg.mlp) + [1]
    mlp = [
        dict(
            w=jax.random.normal(k[4 + i], (mlp_dims[i], mlp_dims[i + 1]))
            * (1.0 / jnp.sqrt(mlp_dims[i])),
            b=jnp.zeros((mlp_dims[i + 1],)),
        )
        for i in range(len(mlp_dims) - 1)
    ]
    blocks = []
    for bi in range(cfg.n_blocks):
        kb = jax.random.split(k[8 + bi], 8)
        s = 1.0 / jnp.sqrt(d)
        blocks.append(dict(
            wq=jax.random.normal(kb[0], (d, d)) * s,
            wk=jax.random.normal(kb[1], (d, d)) * s,
            wv=jax.random.normal(kb[2], (d, d)) * s,
            wo=jax.random.normal(kb[3], (d, d)) * s,
            w1=jax.random.normal(kb[4], (d, cfg.d_ff)) * s,
            w2=jax.random.normal(kb[5], (cfg.d_ff, d)) * (1.0 / jnp.sqrt(cfg.d_ff)),
            ln1=jnp.ones((d,)),
            ln2=jnp.ones((d,)),
        ))
    return dict(
        item_table=jax.random.normal(k[0], (cfg.item_vocab, d)) * 0.03,
        user_table=jax.random.normal(k[1], (cfg.user_vocab, d)) * 0.03,
        field_table=jax.random.normal(
            k[2], (cfg.n_user_fields * cfg.user_field_vocab, d)) * 0.03,
        pos_embed=jax.random.normal(k[3], (seq_total, d)) * 0.03,
        blocks=blocks,
        mlp=mlp,
    )


def param_logical_axes(cfg: BSTConfig):
    block = dict(wq=(None, "heads"), wk=(None, "heads"), wv=(None, "heads"),
                 wo=("heads", None), w1=(None, "mlp"), w2=("mlp", None),
                 ln1=(None,), ln2=(None,))
    return dict(
        item_table=("rows", None),
        user_table=("rows", None),
        field_table=("rows", None),
        pos_embed=(None, None),
        blocks=[block] * cfg.n_blocks,
        mlp=[dict(w=("fsdp", "mlp"), b=(None,))] * (len(cfg.mlp) + 1),
    )


def embedding_bag(table, indices, offsets=None, mode="sum"):
    """EmbeddingBag: gather + segment-reduce (JAX has no native op).

    indices: int32[B, K] (fixed K per bag here: K multi-hot entries per
    field, padded with -1) -> [B, D].
    """
    valid = indices >= 0
    idx = jnp.maximum(indices, 0)
    emb = table[idx] * valid[..., None]
    out = emb.sum(axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(valid.sum(-1, keepdims=True), 1)
    return out


def _ln(x, g, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g


def _block(bp, x, n_heads):
    b, s, d = x.shape
    dh = d // n_heads
    h = _ln(x, bp["ln1"])
    q = (h @ bp["wq"]).reshape(b, s, n_heads, dh)
    k = (h @ bp["wk"]).reshape(b, s, n_heads, dh)
    v = (h @ bp["wv"]).reshape(b, s, n_heads, dh)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh)
    a = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, s, d)
    x = x + o @ bp["wo"]
    h2 = _ln(x, bp["ln2"])
    return x + jax.nn.relu(h2 @ bp["w1"]) @ bp["w2"]


def _encode_sequence(params, behavior, target, cfg: BSTConfig):
    """behavior: int32[B, S], target: int32[B] -> [B, (S+1)*D]."""
    seq = jnp.concatenate([behavior, target[:, None]], axis=1)
    x = params["item_table"][seq] + params["pos_embed"][None]
    for bp in params["blocks"]:
        x = _block(bp, x, cfg.n_heads)
    return x.reshape(x.shape[0], -1)


def bst_forward(params, batch, cfg: BSTConfig):
    """batch: dict(user int32[B], behavior int32[B,S], target int32[B],
    fields int32[B, F, K]) -> CTR logits [B]."""
    seq_flat = _encode_sequence(params, batch["behavior"], batch["target"], cfg)
    user = params["user_table"][batch["user"]]
    # per-field offset into the concatenated field table
    f = cfg.n_user_fields
    offs = (jnp.arange(f, dtype=jnp.int32) * cfg.user_field_vocab)[None, :, None]
    fields = batch["fields"] + jnp.where(batch["fields"] >= 0, offs, 0)
    bags = embedding_bag(params["field_table"], fields)   # [B, F, D]
    bags = bags.reshape(bags.shape[0], -1)
    h = jnp.concatenate([seq_flat, user, bags], axis=-1)
    for i, lp in enumerate(params["mlp"]):
        h = h @ lp["w"] + lp["b"]
        if i < len(params["mlp"]) - 1:
            h = jax.nn.leaky_relu(h)
    return h[:, 0]


def bst_loss(params, batch, cfg: BSTConfig):
    """Binary cross-entropy on CTR labels."""
    logits = bst_forward(params, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def bst_score_candidates(params, batch, candidates, cfg: BSTConfig):
    """Retrieval scoring: one query user vs [N] candidate items.

    The behavior prefix is encoded once; each candidate replaces the target
    slot.  Implemented as a batched forward with the prefix broadcast —
    XLA shares the prefix compute via common-subexpression in practice, and
    candidate work is one [N, ...] batch, not a loop.
    """
    n = candidates.shape[0]
    b = dict(
        user=jnp.broadcast_to(batch["user"], (n,)),
        behavior=jnp.broadcast_to(batch["behavior"], (n, cfg.seq_len)),
        target=candidates,
        fields=jnp.broadcast_to(
            batch["fields"][None], (n,) + tuple(batch["fields"].shape)
        ),
    )
    return bst_forward(params, b, cfg)
