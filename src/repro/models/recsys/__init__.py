"""RecSys models: BST (Behavior Sequence Transformer)."""
from repro.models.recsys.bst import (
    BSTConfig, init_bst, bst_forward, bst_loss, bst_score_candidates,
)

__all__ = ["BSTConfig", "init_bst", "bst_forward", "bst_loss",
           "bst_score_candidates"]
