"""Model zoo: LM transformers (dense + MoE), GNNs, recsys.

Every model family exposes the same functional surface:
  * ``Config`` dataclass (full configs live in repro.configs),
  * ``init_params(key, cfg)`` -> pytree,
  * ``param_logical_axes(cfg)`` -> matching pytree of logical-axis tuples
    consumed by repro.distributed.sharding,
  * pure ``forward`` / ``loss_fn`` functions used by launch/ step builders.
"""
