"""Decoder-only LM transformer: GQA + RoPE, SWA, SwiGLU, top-k MoE, KV cache.

Covers the five assigned LM architectures (mixtral-8x7b/-8x22b,
command-r-35b, smollm-360m, tinyllama-1.1b) from one config dataclass.

Implementation notes (TPU-shaped):
  * Layers are **stacked** ([L, ...] leaves) and driven by ``lax.scan`` with
    optional per-layer remat — compile time and HLO size stay O(1) in depth,
    which matters when lowering 56-layer models against a 512-chip mesh.
  * Attention uses **online-softmax KV chunking** (flash-style at the XLA
    level): peak score memory is [B, H, block_q, block_k], never [S, S].
  * Sliding-window attention masks per chunk; decode uses a **rolling KV
    cache** bounded by the window, which is what makes the 524k-token
    ``long_500k`` cell finite for the Mixtral configs.
  * MoE is sort-based dispatch (tokens sorted by expert, capacity-bounded,
    renormalized top-k combine) — no [T, E, C] dispatch tensor; the buffers
    are 2x activations like the compute itself.  Expert dim shards over the
    'model' mesh axis (expert parallelism; XLA inserts the all-to-alls).
  * Params are stored f32 (master) and cast to ``compute_dtype`` in the
    forward pass; matmuls accumulate f32.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = Any


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 32
    d_ff: int = 512
    vocab: int = 1024
    # MoE (None -> dense SwiGLU)
    n_experts: Optional[int] = None
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_dropless: bool = False      # serving: capacity = T (no token drops)
    # attention
    sliding_window: Optional[int] = None
    rope_theta: float = 1e4
    attn_chunk: int = 1024          # query/kv chunk for online softmax
    attn_impl: str = "chunked"      # chunked (XLA) | flash (Pallas kernel;
                                    # forward-only -> serving/prefill paths)
    # numerics
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    remat_policy: str = "full"      # full | dots  (dots: save matmul outputs)
    scan_layers: bool = True        # False: unrolled (cost-analysis probes)
    # vocab-parallel logits
    tie_embeddings: bool = False

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def is_moe(self) -> bool:
        return self.n_experts is not None

    def cache_len(self, seq_len: int) -> int:
        if self.sliding_window is not None:
            return min(seq_len, self.sliding_window)
        return seq_len

    def param_count(self) -> int:
        """Total parameters (for 6ND roofline accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.d_q + 2 * d * self.d_kv + self.d_q * d
        if self.is_moe:
            mlp = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            mlp = 3 * d * f
        per_layer = attn + mlp + 2 * d
        head = 0 if self.tie_embeddings else d * v
        return v * d + self.n_layers * per_layer + d + head

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = self.param_count() - self.n_layers * (
            self.n_experts - self.top_k
        ) * 3 * d * f
        return dense_like


# --------------------------------------------------------------------------
# init + logical sharding axes
# --------------------------------------------------------------------------

def init_params(key, cfg: LMConfig):
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    L = cfg.n_layers
    k = jax.random.split(key, 12)

    def norm(key, *shape, scale=None):
        import math
        if scale is None:
            scale = 1.0 / math.sqrt(shape[-2] if len(shape) > 1 else d)
        return (jax.random.normal(key, shape, jnp.float32) * scale)

    layers = dict(
        ln1=jnp.ones((L, d), jnp.float32),
        ln2=jnp.ones((L, d), jnp.float32),
        wq=norm(k[0], L, d, cfg.d_q),
        wk=norm(k[1], L, d, cfg.d_kv),
        wv=norm(k[2], L, d, cfg.d_kv),
        wo=norm(k[3], L, cfg.d_q, d),
    )
    if cfg.is_moe:
        E = cfg.n_experts
        layers.update(
            gate=norm(k[4], L, d, E),
            w1=norm(k[5], L, E, d, f),
            w3=norm(k[6], L, E, d, f),
            w2=norm(k[7], L, E, f, d, scale=f ** -0.5),
        )
    else:
        layers.update(
            w1=norm(k[5], L, d, f),
            w3=norm(k[6], L, d, f),
            w2=norm(k[7], L, f, d, scale=f ** -0.5),
        )
    params = dict(
        embed=norm(k[8], v, d, scale=1.0),
        layers=layers,
        final_norm=jnp.ones((d,), jnp.float32),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = norm(k[9], d, v)
    return params


def param_logical_axes(cfg: LMConfig):
    layers = dict(
        ln1=("stack", None),
        ln2=("stack", None),
        wq=("stack", "fsdp", "heads"),
        wk=("stack", "fsdp", "heads"),
        wv=("stack", "fsdp", "heads"),
        wo=("stack", "heads", "fsdp"),
    )
    if cfg.is_moe:
        # experts dim stays unsharded (E=8 does not divide model=16);
        # expert matrices shard 2D: D over fsdp, F over model — 141B-param
        # mixtral-8x22b + f32 Adam then fits 256x16GB (dry-run memory proof)
        layers.update(
            gate=("stack", "fsdp", None),
            w1=("stack", "experts", "fsdp", "mlp"),
            w3=("stack", "experts", "fsdp", "mlp"),
            w2=("stack", "experts", "mlp", "fsdp"),
        )
    else:
        layers.update(
            w1=("stack", "fsdp", "mlp"),
            w3=("stack", "fsdp", "mlp"),
            w2=("stack", "mlp", "fsdp"),
        )
    axes = dict(
        embed=("vocab", "fsdp"),
        layers=layers,
        final_norm=(None,),
    )
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("fsdp", "vocab")
    return axes


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-6):
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms).astype(x.dtype) * g.astype(x.dtype)


def rope(x, positions, theta):
    """x: [B, S, H, Dh]; positions: [B, S] or [S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return rotated.astype(x.dtype)


def _attend_chunked(q, k, v, q_pos, k_pos, window, chunk):
    """Online-softmax attention. q: [B,Sq,Hkv,G,Dh], k/v: [B,Sk,Hkv,Dh].

    q_pos [Sq], k_pos [Sk] are absolute positions (causal + window masks are
    computed from them, so the same code serves train, prefill, and rolling-
    cache decode).  Memory peak: [B, Hkv, G, chunk_q, chunk_k].
    """
    b, sq, hkv, g, dh = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    cq = min(chunk, sq)
    ck = min(chunk, sk)
    n_q, n_k = sq // cq, sk // ck
    q = q.reshape(b, n_q, cq, hkv, g, dh)
    k = k.reshape(b, n_k, ck, hkv, dh)
    v = v.reshape(b, n_k, ck, hkv, dh)
    q_pos = q_pos.reshape(n_q, cq)
    k_pos = k_pos.reshape(n_k, ck)

    def q_block(qi):
        qb = q[:, qi]                       # [B, cq, Hkv, G, Dh]
        qp = q_pos[qi]                      # [cq]

        def kv_step(carry, kj):
            m, l, acc = carry
            kb, vb = k[:, kj], v[:, kj]     # [B, ck, Hkv, Dh]
            kp = k_pos[kj]                  # [ck]
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qb, kb,
                preferred_element_type=jnp.float32,
            ) * scale
            mask = kp[None, :] <= qp[:, None]
            if window is not None:
                mask &= (qp[:, None] - kp[None, :]) < window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, dh), jnp.float32)
        # flash-style backward: remat each kv block so the [cq, ck] score
        # tiles are never saved as scan residuals (else bwd materializes the
        # full S^2 score tensor per layer)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), jnp.arange(n_k)
        )
        out = acc / jnp.maximum(l[..., None], 1e-9)
        return out                           # [B, Hkv, G, cq, Dh]

    outs = jax.lax.map(q_block, jnp.arange(n_q))  # [n_q, B, Hkv, G, cq, Dh]
    out = jnp.moveaxis(outs, 0, 3)                # [B, Hkv, G, n_q, cq, Dh]
    out = out.reshape(b, hkv, g, sq, dh)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, sq, hkv * g * dh)
    return out


def attention(lp, x, cfg: LMConfig, positions, kv=None):
    """Self-attention. If ``kv=(k_cache, v_cache, k_pos)`` attends to the
    cache (decode); otherwise to ``x`` itself (train/prefill)."""
    b, s, _ = x.shape
    hkv, g, dh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.d_head
    dt = cfg.compute_dtype
    q = (x @ lp["wq"].astype(dt)).reshape(b, s, hkv, g, dh)
    k = (x @ lp["wk"].astype(dt)).reshape(b, s, hkv, dh)
    v = (x @ lp["wv"].astype(dt)).reshape(b, s, hkv, dh)
    q = rope(q.reshape(b, s, hkv * g, dh), positions, cfg.rope_theta)
    q = q.reshape(b, s, hkv, g, dh)
    k = rope(k, positions, cfg.rope_theta)
    if kv is None:
        if cfg.attn_impl == "flash":
            from repro.kernels import ops as kops
            out = kops.flash_attention(
                q.reshape(b, s, hkv * g, dh), k, v,
                causal=True, window=cfg.sliding_window,
            ).reshape(b, s, hkv * g * dh)
        else:
            out = _attend_chunked(
                q, k, v, positions, positions, cfg.sliding_window,
                cfg.attn_chunk,
            )
        new_kv = (k, v)
    else:
        k_cache, v_cache, k_pos = kv
        out = _attend_chunked(
            q, k_cache, v_cache,
            positions if positions.ndim == 1 else positions[0],
            k_pos, cfg.sliding_window, cfg.attn_chunk,
        )
        new_kv = None
    return (out.astype(dt) @ lp["wo"].astype(dt)), new_kv


def swiglu(lp, x, dt):
    h = jax.nn.silu(x @ lp["w1"].astype(dt)) * (x @ lp["w3"].astype(dt))
    return h @ lp["w2"].astype(dt)


def moe_mlp(lp, x, cfg: LMConfig, constrain=None):
    """Grouped sort-based top-k MoE with per-group capacity.

    GShard-style groups: each batch row routes its own tokens with local
    capacity ``ceil(cf * K * S / E)``.  The group axis is data-sharded, so
    dispatch (sort/scatter) and the [G, E, cap, D] buffers stay shard-local
    under SPMD — a *global* sort/scatter cannot be value-sharded and forces
    XLA to materialize the full [E*cap_global, D] buffer on every device
    (measured 9.4 GB x ~100 touches/layer on mixtral-8x7b train_4k; §Perf A1).
    """
    b, s, d = x.shape
    dt = cfg.compute_dtype
    E, K = cfg.n_experts, cfg.top_k
    if cfg.moe_dropless:
        cap = s                      # worst-case skew: no drops (serving)
    else:
        cap = min(max(-(-int(cfg.capacity_factor * K * s) // E), 1), s)

    def dispatch(xt):
        """xt: [S, D] -> buffer [E, cap, D] + combine indices."""
        logits = (xt @ lp["gate"].astype(dt)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, tope = jax.lax.top_k(probs, K)                # [S, K]
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        flat_e = tope.reshape(-1).astype(jnp.int32)         # [S*K]
        flat_t = jnp.repeat(jnp.arange(s, dtype=jnp.int32), K)
        flat_w = topv.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        start = jnp.searchsorted(se, jnp.arange(E, dtype=jnp.int32))
        pos = jnp.arange(s * K, dtype=jnp.int32) - start[se]
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, E * cap)     # dropped -> tail
        buf = jnp.zeros((E * cap + 1, d), dt).at[slot].set(
            xt[st] * keep[:, None].astype(dt)
        )
        return buf[: E * cap].reshape(E, cap, d), st, sw, keep, slot

    h, st, sw, keep, slot = jax.vmap(dispatch)(x)           # h: [B,E,cap,D]
    # keep the group dim batch-sharded through the expert einsums: without
    # the constraint XLA reshards the [G,E,cap,*] buffers to the FSDP weight
    # layout (full G on every chip) instead of gathering the far smaller
    # weight shards (§Perf A2)
    if constrain is not None:
        h = constrain(h)
    up = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", h, lp["w1"].astype(dt))
    ) * jnp.einsum("gecd,edf->gecf", h, lp["w3"].astype(dt))
    if constrain is not None:
        up = constrain(up)
    down = jnp.einsum("gecf,efd->gecd", up, lp["w2"].astype(dt))
    if constrain is not None:
        down = constrain(down)

    def combine(down_g, st_g, sw_g, keep_g, slot_g):
        flat = jnp.concatenate(
            [down_g.reshape(E * cap, d), jnp.zeros((1, d), dt)], axis=0)
        return jnp.zeros((s, d), dt).at[st_g].add(
            flat[slot_g] * (sw_g * keep_g)[:, None].astype(dt)
        )

    return jax.vmap(combine)(down, st, sw, keep, slot)


def _layer(lp, x, cfg: LMConfig, positions, kv=None, constrain=None):
    h, new_kv = attention(lp, rmsnorm(x, lp["ln1"]), cfg, positions, kv)
    x = x + h
    h2 = rmsnorm(x, lp["ln2"])
    if cfg.is_moe:
        x = x + moe_mlp(lp, h2, cfg, constrain)
    else:
        x = x + swiglu(lp, h2, cfg.compute_dtype)
    if constrain is not None:
        x = constrain(x)
    return x, new_kv


# --------------------------------------------------------------------------
# public forward passes
# --------------------------------------------------------------------------

def forward(params, tokens, cfg: LMConfig, constrain=None):
    """Train/prefill forward. tokens: int32[B, S] -> logits [B, S, V]."""
    b, s = tokens.shape
    dt = cfg.compute_dtype
    x = params["embed"].astype(dt)[tokens]
    if constrain is not None:
        x = constrain(x)
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(x, lp):
        base = partial(_layer, cfg=cfg, positions=positions, constrain=constrain)
        if cfg.remat:
            policy = None
            if cfg.remat_policy == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            ck = jax.checkpoint(lambda p, h: base(p, h)[0], policy=policy)
            return ck(lp, x), None
        return base(lp, x)[0], None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:  # unrolled: exact cost_analysis (scan bodies are counted once)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, _ = body(x, lp)
    x = rmsnorm(x, params["final_norm"])
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    logits = (x @ head.astype(dt)).astype(jnp.float32)
    return logits


def loss_fn(params, tokens, targets, cfg: LMConfig, constrain=None):
    """Next-token cross-entropy (mean over tokens)."""
    logits = forward(params, tokens, cfg, constrain)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---- serving -------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, seq_len: int):
    """Allocate the KV cache for decode at context length ``seq_len``.

    SWA models use a rolling buffer bounded by the window: the 524k-token
    long-context cell costs the same cache as a 4k one.
    """
    cl = cfg.cache_len(seq_len)
    shape = (cfg.n_layers, batch, cl, cfg.n_kv_heads, cfg.d_head)
    return dict(
        k=jnp.zeros(shape, cfg.compute_dtype),
        v=jnp.zeros(shape, cfg.compute_dtype),
        pos=jnp.zeros((cfg.n_layers, batch, cl), jnp.int32) - 1,
        t=jnp.zeros((), jnp.int32) + seq_len,  # absolute decode position
    )


def decode_step(params, cache, tokens, cfg: LMConfig, constrain=None):
    """One decode step. tokens: int32[B] -> (logits [B, V], new cache)."""
    b = tokens.shape[0]
    dt = cfg.compute_dtype
    t = cache["t"]
    x = params["embed"].astype(dt)[tokens][:, None, :]      # [B, 1, D]
    positions = jnp.full((b, 1), t, jnp.int32)
    cl = cache["k"].shape[2]
    slot = t % cl                                            # rolling slot

    def body(x, per_layer):
        lp, kc, vc, pc = per_layer
        h1 = rmsnorm(x, lp["ln1"])
        hkv, g, dh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.d_head
        q = (h1 @ lp["wq"].astype(dt)).reshape(b, 1, hkv, g, dh)
        k = (h1 @ lp["wk"].astype(dt)).reshape(b, 1, hkv, dh)
        v = (h1 @ lp["wv"].astype(dt)).reshape(b, 1, hkv, dh)
        q = rope(q.reshape(b, 1, hkv * g, dh), positions, cfg.rope_theta)
        q = q.reshape(b, 1, hkv, g, dh)
        k = rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        pc = jax.lax.dynamic_update_slice(pc, positions, (0, slot))
        # score against the whole cache; stale slots masked via positions
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kc,
                       preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(dh)
        valid = (pc >= 0) & (pc <= t)
        if cfg.sliding_window is not None:
            valid &= (t - pc) < cfg.sliding_window
        s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(dt), vc)
        o = o.reshape(b, 1, cfg.d_q) @ lp["wo"].astype(dt)
        x = x + o
        h2 = rmsnorm(x, lp["ln2"])
        if cfg.is_moe:
            x = x + moe_mlp(lp, h2, cfg)
        else:
            x = x + swiglu(lp, h2, dt)
        return x, (kc, vc, pc)

    if cfg.scan_layers:
        x, (k_new, v_new, p_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"], cache["pos"])
        )
    else:  # unrolled (cost-analysis probes)
        ks, vs, ps = [], [], []
        for i in range(cfg.n_layers):
            per = jax.tree.map(
                lambda a: a[i],
                (params["layers"], cache["k"], cache["v"], cache["pos"]),
            )
            x, (kc, vc, pc) = body(x, per)
            ks.append(kc)
            vs.append(vc)
            ps.append(pc)
        k_new = jnp.stack(ks)
        v_new = jnp.stack(vs)
        p_new = jnp.stack(ps)
    x = rmsnorm(x, params["final_norm"])
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    logits = (x[:, 0] @ head.astype(dt)).astype(jnp.float32)
    new_cache = dict(k=k_new, v=v_new, pos=p_new, t=t + 1)
    return logits, new_cache
