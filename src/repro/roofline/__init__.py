"""Roofline analysis from compiled dry-run artifacts."""
from repro.roofline.hw import HW
from repro.roofline.analyze import analyze_compiled, collective_bytes

__all__ = ["HW", "analyze_compiled", "collective_bytes"]
