"""Hardware model for the roofline (TPU v5e-like, per task spec).

All roofline terms in EXPERIMENTS.md §Roofline are computed against these
constants; they are deliberately centralized so perf iterations change code,
never the yardstick.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class _HW:
    peak_flops_bf16: float = 197e12     # FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_link_bw: float = 50e9           # B/s per link per direction
    ici_links: int = 2                  # effective links engaged per chip for
                                        # ring collectives on the sharded axis
    vmem_bytes: int = 128 * 1024 * 1024  # not a roofline term; kernel budget

    @property
    def ici_bw(self) -> float:
        return self.ici_link_bw * self.ici_links


HW = _HW()
