"""Roofline terms from compiled XLA artifacts.

``cost_analysis`` supplies HLO FLOPs and bytes-accessed; collective traffic
is *not* in cost_analysis, so :func:`collective_bytes` parses the optimized
HLO text and sums operand sizes of every collective op, bucketed by kind.

Terms (seconds, per step, per chip):
  t_comp = flops_dev / peak
  t_mem  = bytes_dev / hbm_bw
  t_coll = coll_bytes_dev / ici_bw

``cost_analysis`` of an SPMD-partitioned executable reports **per-device**
flops/bytes (verified empirically against analytic 6ND in
EXPERIMENTS.md §Dry-run), and post-partitioning HLO shapes are per-device
too, so every term is already chip-local; ``model_flops`` (global) is
divided by chip count before forming ratios.
"""
from __future__ import annotations

import re
from typing import Any

from repro.roofline.hw import HW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective in optimized HLO.

    Returns {kind: bytes} plus 'total'.  Output-shape accounting counts each
    collective's payload once (all-gather output = full gathered tensor;
    all-reduce output = reduced tensor), a consistent proxy for link traffic
    up to the (chips-1)/chips ring factor, which we fold into HW.ici_bw.
    """
    out: dict = {k: 0 for k in _COLLECTIVES}
    n_ops: dict = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "<shape> <op-name> = opcode(...)" in optimized HLO: opcode
        # appears after '=', e.g. "%ag = bf16[4096,512] all-gather(...)"
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        for kind in _COLLECTIVES:
            # opcode token, avoid matching fused computation names
            if re.search(rf"\b{kind}(-start|-done)?\(", rhs):
                lhs_shape = rhs.split(kind)[0]
                b = _shape_bytes(lhs_shape)
                if f"{kind}-done(" in rhs:
                    continue  # -start already counted
                out[kind] += b
                n_ops[kind] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["n_ops"] = {k: v for k, v in n_ops.items() if v}
    return out


def analyze_compiled(compiled, chips: int, *, model_flops: float | None = None,
                     hlo_text: str | None = None) -> dict:
    """Roofline record for one compiled executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    t_comp = flops / HW.peak_flops_bf16
    t_mem = byts / HW.hbm_bw
    t_coll = coll["total"] / HW.ici_bw
    terms = dict(compute=t_comp, memory=t_mem, collective=t_coll)
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    rec = dict(
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=byts,
        collective_bytes=coll["total"],
        collective_breakdown={k: v for k, v in coll.items()
                              if k in _COLLECTIVES and v},
        collective_ops=coll.get("n_ops", {}),
        t_compute=t_comp,
        t_memory=t_mem,
        t_collective=t_coll,
        bottleneck=bottleneck,
        step_time_bound=step_time,
    )
    if model_flops:
        mf_dev = float(model_flops) / chips
        rec["model_flops"] = float(model_flops)
        rec["useful_flops_ratio"] = mf_dev / max(flops, 1.0)
        # roofline fraction: useful work at peak vs bound step time
        rec["roofline_fraction"] = (
            mf_dev / HW.peak_flops_bf16
        ) / max(step_time, 1e-12)
    try:
        mem = compiled.memory_analysis()
        rec["bytes_per_device"] = dict(
            argument=int(getattr(mem, "argument_size_in_bytes", 0)),
            output=int(getattr(mem, "output_size_in_bytes", 0)),
            temp=int(getattr(mem, "temp_size_in_bytes", 0)),
            peak=int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
        )
    except Exception:  # pragma: no cover - memory analysis is best-effort
        pass
    return rec
