"""Pluggable metric sinks and the telemetry hub.

Callback-style observability: the service emits counters, gauges,
histogram observations, and completed request spans into a
:class:`Telemetry` hub, and any number of registered :class:`MetricSink`
subclasses receive them (``on_counter`` / ``on_gauge`` /
``on_histogram`` / ``on_span``).  Built-ins:

* :class:`InMemorySink` — thread-safe aggregation (counters sum, gauges
  keep last, observations stream into
  :class:`repro.telemetry.histogram.StreamingHistogram`); backs the
  Prometheus exporter and the replay harness's phase breakdown.
* :class:`JsonlSink` — one JSON line per event, for offline analysis.

Write a custom sink by subclassing :class:`MetricSink` and overriding
any subset of the hooks (see ``examples/telemetry_sinks.py``).  Sink
errors are isolated: a raising sink never breaks the serving path (the
first error per sink is recorded on ``hub.sink_errors``, bounded at
``Telemetry.max_sink_errors`` with a drop counter).

The hub is cheap when nothing listens: every emit method early-outs on
an empty sink tuple, so a telemetry-disabled service pays one attribute
load + truth test per event.
"""
from __future__ import annotations

import json
import threading
from collections import OrderedDict
from typing import Dict, Optional, TextIO, Tuple

from repro.telemetry.histogram import StreamingHistogram
from repro.telemetry.spans import RequestTrace, Span

# labels are flattened to a hashable, order-independent key
LabelKey = Tuple[Tuple[str, str], ...]


def label_key(labels: Optional[Dict[str, str]]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricSink:
    """Base class: override any subset of the event hooks."""

    def on_counter(self, name: str, value: float,
                   labels: Optional[Dict[str, str]] = None):
        pass

    def on_gauge(self, name: str, value: float,
                 labels: Optional[Dict[str, str]] = None):
        pass

    def on_histogram(self, name: str, value: float,
                     labels: Optional[Dict[str, str]] = None):
        pass

    def on_span(self, span: Span):
        pass

    def close(self):
        pass


class Telemetry:
    """The hub: emit-side API for the service, registry for sinks.

    Sink exceptions never break serving: ``_guard`` records the first
    error per sink in ``sink_errors``, bounded at ``max_sink_errors``
    entries (oldest dropped, counted in ``n_sink_errors_dropped``) so a
    long-lived service churning through failing sinks cannot grow the
    record without bound; ``n_sink_errors`` counts every guarded raise.
    """

    max_sink_errors = 16

    def __init__(self):
        self._sinks: Tuple[MetricSink, ...] = ()
        self._lock = threading.Lock()
        self.sink_errors: "OrderedDict[int, BaseException]" = OrderedDict()
        self.n_sink_errors = 0
        self.n_sink_errors_dropped = 0

    # -- registry ---------------------------------------------------------
    def register(self, sink: MetricSink) -> MetricSink:
        with self._lock:
            self._sinks = self._sinks + (sink,)
        return sink

    def unregister(self, sink: MetricSink):
        with self._lock:
            self._sinks = tuple(s for s in self._sinks if s is not sink)

    @property
    def sinks(self) -> Tuple[MetricSink, ...]:
        return self._sinks

    @property
    def enabled(self) -> bool:
        return bool(self._sinks)

    def close(self):
        sinks, self._sinks = self._sinks, ()
        for s in sinks:
            self._guard(s, s.close)

    # -- emit -------------------------------------------------------------
    def counter(self, name: str, value: float = 1.0,
                labels: Optional[Dict[str, str]] = None):
        for s in self._sinks:
            self._guard(s, s.on_counter, name, value, labels)

    def gauge(self, name: str, value: float,
              labels: Optional[Dict[str, str]] = None):
        for s in self._sinks:
            self._guard(s, s.on_gauge, name, value, labels)

    def observe(self, name: str, value: float,
                labels: Optional[Dict[str, str]] = None):
        for s in self._sinks:
            self._guard(s, s.on_histogram, name, value, labels)

    def span(self, span: Span):
        for s in self._sinks:
            self._guard(s, s.on_span, span)

    def trace(self, trace: RequestTrace):
        """Broadcast every span of a completed request trace."""
        if not self._sinks:
            return
        for sp in trace.spans:
            self.span(sp)

    def _guard(self, sink: MetricSink, fn, *args):
        try:
            fn(*args)
        except Exception as e:          # sink bugs never break serving
            with self._lock:
                self.n_sink_errors += 1
                if id(sink) not in self.sink_errors:
                    self.sink_errors[id(sink)] = e
                    while len(self.sink_errors) > self.max_sink_errors:
                        self.sink_errors.popitem(last=False)
                        self.n_sink_errors_dropped += 1


class InMemorySink(MetricSink):
    """Thread-safe aggregation: the default sink behind ``/metrics``.

    ``counters[(name, labels)] -> float`` (summed),
    ``gauges[(name, labels)] -> float`` (last write wins),
    ``histograms[(name, labels)] -> StreamingHistogram``.
    Spans aggregate into ``histograms[("span_duration_seconds",
    (("phase", name),))]`` so per-phase latency distributions fall out
    without custom plumbing.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: Dict[Tuple[str, LabelKey], float] = {}
        self.gauges: Dict[Tuple[str, LabelKey], float] = {}
        self.histograms: Dict[Tuple[str, LabelKey], StreamingHistogram] = {}
        self.n_spans = 0

    def on_counter(self, name, value, labels=None):
        k = (name, label_key(labels))
        with self._lock:
            self.counters[k] = self.counters.get(k, 0.0) + float(value)

    def on_gauge(self, name, value, labels=None):
        with self._lock:
            self.gauges[(name, label_key(labels))] = float(value)

    def on_histogram(self, name, value, labels=None):
        k = (name, label_key(labels))
        with self._lock:
            h = self.histograms.get(k)
            if h is None:
                h = self.histograms[k] = StreamingHistogram()
        h.add(value)

    def on_span(self, span: Span):
        self.n_spans += 1
        self.on_histogram("span_duration_seconds", span.duration_s,
                          {"phase": span.name})

    # -- queries ----------------------------------------------------------
    def counter_value(self, name: str, labels=None) -> float:
        return self.counters.get((name, label_key(labels)), 0.0)

    def counter_total(self, name: str) -> float:
        """Sum of a counter across all label sets."""
        return sum(v for (n, _), v in self.counters.items() if n == name)

    def histogram(self, name: str, labels=None) -> Optional[StreamingHistogram]:
        return self.histograms.get((name, label_key(labels)))

    def phase_durations(self) -> Dict[str, StreamingHistogram]:
        """phase name -> latency histogram, from aggregated spans."""
        out = {}
        for (name, lk), h in self.histograms.items():
            if name == "span_duration_seconds":
                labels = dict(lk)
                out[labels.get("phase", "?")] = h
        return out

    def phase_breakdown(self) -> Dict[str, float]:
        """queue / engine / host share of total per-request span time
        (fractions summing to 1.0 when any spans were recorded)."""
        from repro.telemetry.spans import phase_group
        totals = {"queue": 0.0, "engine": 0.0, "host": 0.0}
        for phase, h in self.phase_durations().items():
            totals[phase_group(phase)] += h.sum
        grand = sum(totals.values())
        if grand <= 0.0:
            return {k: 0.0 for k in totals}
        return {k: v / grand for k, v in totals.items()}

    def reset(self):
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()
            self.n_spans = 0


class JsonlSink(MetricSink):
    """One JSON line per event, to a path or an open text stream."""

    def __init__(self, path_or_stream):
        if hasattr(path_or_stream, "write"):
            self._f: TextIO = path_or_stream
            self._owned = False
        else:
            self._f = open(path_or_stream, "a")
            self._owned = True
        self._lock = threading.Lock()

    def _emit(self, obj: dict):
        line = json.dumps(obj, separators=(",", ":"))
        with self._lock:
            self._f.write(line + "\n")

    def on_counter(self, name, value, labels=None):
        self._emit(dict(ev="counter", name=name, value=value,
                        labels=labels or {}))

    def on_gauge(self, name, value, labels=None):
        self._emit(dict(ev="gauge", name=name, value=value,
                        labels=labels or {}))

    def on_histogram(self, name, value, labels=None):
        self._emit(dict(ev="histogram", name=name, value=value,
                        labels=labels or {}))

    def on_span(self, span: Span):
        self._emit(dict(ev="span", **span.as_dict()))

    def close(self):
        with self._lock:
            self._f.flush()
            if self._owned:
                self._f.close()
