"""Structured telemetry for the community-detection service.

Four layers (see README "Observability"):

* :mod:`repro.telemetry.spans` — per-request lifecycle traces
  (``submit -> ... -> resolve``) with monotonic-clock spans.
* :mod:`repro.telemetry.sinks` — the :class:`Telemetry` hub plus
  pluggable :class:`MetricSink` callbacks (in-memory aggregation, JSONL
  event log, custom).
* :mod:`repro.telemetry.histogram` — fixed-size streaming latency
  histograms (replaces the unbounded lists ``service/metrics.py`` used).
* :mod:`repro.telemetry.prometheus` — text-format exporter over stdlib
  ``http.server`` plus a parser for scrape assertions.
"""
from repro.telemetry.histogram import StreamingHistogram
from repro.telemetry.prometheus import (
    MetricsExporter, metric_names, parse_prometheus, render_prometheus,
)
from repro.telemetry.sinks import (
    InMemorySink, JsonlSink, MetricSink, Telemetry,
)
from repro.telemetry.spans import (
    PHASES, RequestTrace, Span, phase_group,
)

__all__ = [
    "StreamingHistogram",
    "MetricsExporter", "metric_names", "parse_prometheus",
    "render_prometheus",
    "InMemorySink", "JsonlSink", "MetricSink", "Telemetry",
    "PHASES", "RequestTrace", "Span", "phase_group",
]
