"""Span/trace layer: per-request lifecycle timing.

A request through the service passes a fixed set of phases::

    submit -> admission -> queue-wait -> drr-compose -> repad ->
    compile(hit/miss) -> engine-dispatch -> device-sync ->
    store-commit -> resolve

Each phase is recorded as a :class:`Span` — a name plus monotonic-clock
``(t_start, t_end)`` — inside the request's :class:`RequestTrace`.  The
trace id is the request id (``d17-gid`` / ``u3-gid``), surfaced on
``DetectionFuture.trace`` so callers can inspect where their time went
without any global registry.

Per-request phases (``submit``, ``admission``, ``queue-wait``,
``repad``, ``store-commit``, ``resolve``) are marked individually;
batch-level phases (``drr-compose``, ``compile``, ``engine-dispatch``,
``device-sync``) happen once per dispatched batch and are stamped onto
every member request's trace with the same interval — a trace therefore
reads as "this request's batch spent X in the engine", which is the
number that matters for per-phase latency attribution.

Spans carry optional string labels (e.g. ``compile`` marks
``hit="true"|"false"``).  Completed traces are broadcast to the
telemetry hub (:mod:`repro.telemetry.sinks`) at resolve time.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, List, Optional

# canonical phase taxonomy, in lifecycle order (docs + tests key off this)
PHASES = (
    "submit",          # entry-point work before enqueue (validate, repad..)
    "admission",       # bound check + locked enqueue
    "queue-wait",      # enqueue -> popped by DRR compose
    "drr-compose",     # weighted-DRR batch composition
    "repad",           # bucket padding (inside submit on the detect path)
    "compile",         # jit cache consult; labels: hit=true|false
    "engine-dispatch", # traced jax dispatch (host -> device)
    "device-sync",     # device -> host transfer + np conversion
    "store-commit",    # versioned store write
    "resolve",         # future resolution fan-out
)

# phases grouped for the replay harness's breakdown report
PHASE_GROUPS: Dict[str, str] = {
    "queue-wait": "queue",
    "compile": "engine",
    "engine-dispatch": "engine",
    "device-sync": "engine",
}


def phase_group(name: str) -> str:
    """queue / engine / host bucket for a span name."""
    return PHASE_GROUPS.get(name, "host")


@dataclasses.dataclass
class Span:
    """One timed phase of a request (monotonic-clock endpoints)."""

    name: str
    t_start: float
    t_end: float
    trace_id: str = ""
    labels: Optional[Dict[str, str]] = None

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start

    def as_dict(self) -> dict:
        d = dict(name=self.name, trace_id=self.trace_id,
                 t_start=self.t_start, t_end=self.t_end,
                 duration_s=self.duration_s)
        if self.labels:
            d["labels"] = dict(self.labels)
        return d


class RequestTrace:
    """Ordered spans for one request; the trace id is the request id."""

    __slots__ = ("trace_id", "tenant", "kind", "spans", "clock")

    def __init__(self, trace_id: str, *, tenant: str = "default",
                 kind: str = "detect",
                 clock: Optional[Callable[[], float]] = None):
        self.trace_id = trace_id
        self.tenant = tenant
        self.kind = kind
        self.spans: List[Span] = []
        self.clock = clock or time.perf_counter

    def mark(self, name: str, t_start: float, t_end: float,
             **labels: str) -> Span:
        """Record a phase from externally-measured endpoints (used for
        batch-level phases stamped onto every member request)."""
        s = Span(name, float(t_start), float(t_end), self.trace_id,
                 labels or None)
        self.spans.append(s)
        return s

    @contextlib.contextmanager
    def span(self, name: str, **labels: str):
        """Context-manager phase: ``with trace.span("repad"): ...``."""
        t0 = self.clock()
        try:
            yield
        finally:
            self.mark(name, t0, self.clock(), **labels)

    def durations(self) -> Dict[str, float]:
        """Total seconds per phase name (a repeated phase accumulates)."""
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.duration_s
        return out

    def find(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def __len__(self) -> int:
        return len(self.spans)

    def __repr__(self):
        parts = ", ".join(f"{s.name}={s.duration_s * 1e3:.2f}ms"
                          for s in self.spans)
        return f"RequestTrace({self.trace_id!r}: {parts})"
