"""Fixed-size streaming latency histograms.

The service metrics used to keep every observed latency in an append-only
Python list — unbounded memory under sustained traffic.  A
:class:`StreamingHistogram` replaces the list with a fixed-size array of
log-spaced buckets: O(1) per observation, ~10 KB resident forever, and
percentiles within the bucket resolution.

Resolution contract: bucket bounds grow by ``GROWTH`` (2%) per bucket and
the reported percentile is the geometric midpoint of its bucket, so the
relative error is bounded by ``sqrt(GROWTH) - 1`` (~1%) — tight enough
that the service's p50/p99 reporting is indistinguishable from the exact
list-based math it replaced (asserted in tests/test_telemetry.py).  Exact
min/max are tracked on the side so the extreme percentiles (p0/p100) and
midpoints clamp to observed values.

The same class backs the in-memory aggregation sink and the Prometheus
exporter (:meth:`cumulative_le` renders the classic ``le`` bucket ladder
from the fine internal buckets).
"""
from __future__ import annotations

import math

import numpy as np

# bucket i covers [LO * GROWTH^i, LO * GROWTH^(i+1)); values below LO land
# in an underflow bucket, values above HI in an overflow bucket.  LO..HI
# spans 100ns..10^4s — any service latency representable.
LO = 1e-7
HI = 1e4
GROWTH = 1.02
_LOG_G = math.log(GROWTH)
N_BUCKETS = int(math.ceil(math.log(HI / LO) / _LOG_G))


class StreamingHistogram:
    """Log-bucketed streaming histogram over positive values (seconds)."""

    __slots__ = ("counts", "n", "total", "vmin", "vmax")

    def __init__(self):
        # +2: underflow at [0], overflow at [-1]
        self.counts = np.zeros(N_BUCKETS + 2, np.int64)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def add(self, x: float):
        x = float(x)
        if x != x:                       # NaN observations are dropped
            return
        self.n += 1
        self.total += x
        if x < self.vmin:
            self.vmin = x
        if x > self.vmax:
            self.vmax = x
        if x < LO:
            idx = 0
        elif x >= HI:
            idx = N_BUCKETS + 1
        else:
            idx = 1 + int(math.log(x / LO) / _LOG_G)
            idx = min(idx, N_BUCKETS)
        self.counts[idx] += 1

    def __len__(self) -> int:
        return self.n

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else float("nan")

    @property
    def sum(self) -> float:
        return self.total

    @staticmethod
    def _edges(idx: int) -> tuple:
        """(lo, hi) value bounds of internal bucket ``idx``."""
        if idx == 0:
            return 0.0, LO
        if idx == N_BUCKETS + 1:
            return HI, math.inf
        return LO * GROWTH ** (idx - 1), LO * GROWTH ** idx

    def percentile(self, p: float) -> float:
        """Approximate percentile (geometric bucket midpoint, clamped to
        the exact observed min/max).  ``nan`` when empty."""
        if not self.n:
            return float("nan")
        target = max(1, math.ceil(self.n * float(p) / 100.0))
        cum = 0
        for idx, c in enumerate(self.counts):
            if not c:
                continue
            cum += int(c)
            if cum >= target:
                lo, hi = self._edges(idx)
                mid = math.sqrt(lo * hi) if lo > 0.0 and hi < math.inf \
                    else (hi if lo == 0.0 else lo)
                return float(min(max(mid, self.vmin), self.vmax))
        return float(self.vmax)

    def cumulative_le(self, edge: float) -> int:
        """Observations known to be ``<= edge`` (Prometheus ``le``
        semantics; conservative — a bucket counts only when its whole
        range is below the edge, plus the exact-max refinement)."""
        if edge == math.inf:
            return self.n
        cum = 0
        for idx, c in enumerate(self.counts):
            if not c:
                continue
            lo, hi = self._edges(idx)
            if hi <= edge:
                cum += int(c)
        return cum

    def merge(self, other: "StreamingHistogram"):
        self.counts += other.counts
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    def __repr__(self):
        if not self.n:
            return "StreamingHistogram(empty)"
        return (f"StreamingHistogram(n={self.n}, mean={self.mean:.2e}, "
                f"p50={self.percentile(50):.2e}, "
                f"p99={self.percentile(99):.2e})")
