"""Prometheus text-format exporter — stdlib only, no client library.

Three pieces:

* :func:`render_prometheus` — serialize an :class:`InMemorySink` into
  Prometheus text exposition format 0.0.4 (counters with ``_total``,
  gauges, histograms as the classic cumulative ``le`` bucket ladder plus
  ``_sum``/``_count``).
* :func:`parse_prometheus` — a minimal parser for the same format, used
  by the CI smoke to assert a scrape round-trips (``scrape -> parse ->
  expected families present``).
* :class:`MetricsExporter` — a daemon-threaded stdlib ``http.server``
  serving ``GET /metrics``; ``port=0`` binds an ephemeral port
  (``exporter.port`` reports the real one).  ``dump()`` renders without
  HTTP for tests.
"""
from __future__ import annotations

import math
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

from repro.telemetry.sinks import InMemorySink

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# coarse exported bucket ladder (seconds): fine internal buckets collapse
# onto this so a scrape stays small while p50/p99 queries stay useful
DEFAULT_EDGES = (1e-5, 1e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
                 5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0, 60.0)

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _clean(name: str) -> str:
    return _NAME_OK.sub("_", name)


def _fmt_labels(lk) -> str:
    if not lk:
        return ""
    inner = ",".join(f'{_clean(k)}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
                     for k, v in lk)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v != v:
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def render_prometheus(sink: InMemorySink, *, prefix: str = "repro",
                      edges=DEFAULT_EDGES) -> str:
    """Serialize the sink's aggregates as Prometheus text format."""
    lines: List[str] = []
    pfx = _clean(prefix) + "_" if prefix else ""

    by_name: Dict[str, list] = {}
    for (name, lk), v in sorted(sink.counters.items()):
        by_name.setdefault(name, []).append((lk, v))
    for name, rows in by_name.items():
        full = pfx + _clean(name)
        if not full.endswith("_total"):
            full += "_total"
        lines.append(f"# TYPE {full} counter")
        for lk, v in rows:
            lines.append(f"{full}{_fmt_labels(lk)} {_fmt_value(v)}")

    by_name = {}
    for (name, lk), v in sorted(sink.gauges.items()):
        by_name.setdefault(name, []).append((lk, v))
    for name, rows in by_name.items():
        full = pfx + _clean(name)
        lines.append(f"# TYPE {full} gauge")
        for lk, v in rows:
            lines.append(f"{full}{_fmt_labels(lk)} {_fmt_value(v)}")

    by_name = {}
    for (name, lk), h in sorted(sink.histograms.items()):
        by_name.setdefault(name, []).append((lk, h))
    for name, rows in by_name.items():
        full = pfx + _clean(name)
        lines.append(f"# TYPE {full} histogram")
        for lk, h in rows:
            for edge in edges:
                cum = h.cumulative_le(edge)
                le = dict(lk)
                le["le"] = _fmt_value(edge)
                lines.append(f"{full}_bucket{_fmt_labels(tuple(sorted(le.items())))} {cum}")
            inf = dict(lk)
            inf["le"] = "+Inf"
            lines.append(f"{full}_bucket{_fmt_labels(tuple(sorted(inf.items())))} {h.n}")
            lines.append(f"{full}_sum{_fmt_labels(lk)} {_fmt_value(h.sum)}")
            lines.append(f"{full}_count{_fmt_labels(lk)} {h.n}")

    return "\n".join(lines) + "\n"


_SAMPLE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})?'
    r'\s+(?P<value>[^\s]+)\s*$')
_LABEL = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:\\.|[^"\\])*)"')


def parse_prometheus(text: str) -> Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float]:
    """Parse text exposition format into {(name, labels): value}.

    Minimal but strict on sample lines: a non-comment line that fails to
    parse raises ValueError (the CI smoke uses this to assert the
    exporter emits valid format).  Returns type metadata separately via
    :func:`parse_prometheus_types` if needed.
    """
    out: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float] = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if m is None:
            raise ValueError(f"unparseable Prometheus sample line: {raw!r}")
        labels = tuple(sorted(
            (k, v.replace('\\"', '"').replace("\\\\", "\\"))
            for k, v in _LABEL.findall(m.group("labels") or "")))
        val_s = m.group("value")
        if val_s == "+Inf":
            val = math.inf
        elif val_s == "-Inf":
            val = -math.inf
        else:
            val = float(val_s)
        out[(m.group("name"), labels)] = val
    return out


def metric_names(parsed) -> set:
    return {name for name, _ in parsed}


class MetricsExporter:
    """``GET /metrics`` over stdlib ``http.server`` (daemon thread)."""

    def __init__(self, sink: InMemorySink, *, port: int = 0,
                 host: str = "127.0.0.1", prefix: str = "repro"):
        self.sink = sink
        self.prefix = prefix
        exporter = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):           # noqa: N802 (stdlib API)
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = exporter.dump().encode()
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # silence per-request stderr spam
                pass

        self._server = ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self._thread = threading.Thread(
            target=self._server.serve_forever, daemon=True,
            name="telemetry-exporter")
        self._thread.start()

    @property
    def port(self) -> int:
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        host = self._server.server_address[0]
        return f"http://{host}:{self.port}/metrics"

    def dump(self) -> str:
        """Render the current scrape body without HTTP (for tests)."""
        return render_prometheus(self.sink, prefix=self.prefix)

    def close(self):
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)
