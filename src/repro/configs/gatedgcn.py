"""GatedGCN [arXiv:2003.00982; paper] — 16 layers, 70 hidden, gated
aggregation (benchmarking-gnns configuration)."""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import GatedGCNConfig

CONFIG = GatedGCNConfig(name="gatedgcn", n_layers=16, d_hidden=70)
SMOKE = GatedGCNConfig(name="gatedgcn-smoke", n_layers=3, d_in=12,
                       d_hidden=16, n_classes=3)

SPEC = ArchSpec(
    arch_id="gatedgcn",
    family="gnn",
    config=CONFIG,
    smoke=SMOKE,
    shapes=GNN_SHAPES,
    source="[arXiv:2003.00982; paper]",
)
