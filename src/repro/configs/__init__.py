"""Architecture configs (one module per assigned architecture)."""
from repro.configs.base import ARCH_IDS, ArchSpec, get_spec, all_cells

__all__ = ["ARCH_IDS", "ArchSpec", "get_spec", "all_cells"]
