"""GCN (cora config) [arXiv:1609.02907; paper] — 2 layers, 16 hidden,
mean/sym aggregation.  d_in / n_classes adapt to each assigned shape."""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import GCNConfig

CONFIG = GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16, norm="sym")
SMOKE = GCNConfig(name="gcn-smoke", n_layers=2, d_in=12, d_hidden=8,
                  n_classes=3, norm="sym")

SPEC = ArchSpec(
    arch_id="gcn-cora",
    family="gnn",
    config=CONFIG,
    smoke=SMOKE,
    shapes=GNN_SHAPES,
    source="[arXiv:1609.02907; paper]",
)
