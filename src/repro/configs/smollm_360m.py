"""SmolLM 360M [hf:HuggingFaceTB/SmolLM-360M; hf] — llama-arch small:
32L 960d 15H (GQA kv=5), d_ff=2560, vocab 49152."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="smollm-360m",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_head=64,
    d_ff=2560, vocab=49152,
    sliding_window=None, rope_theta=1e4,
    compute_dtype=jnp.bfloat16, remat=True,
)

SMOKE = LMConfig(
    name="smollm-smoke",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, d_head=20,
    d_ff=160, vocab=128,
    compute_dtype=jnp.float32, remat=False, attn_chunk=16,
)

SPEC = ArchSpec(
    arch_id="smollm-360m",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    shapes=LM_SHAPES,
    skip_shapes=dict(
        long_500k="pure full attention (quadratic); skipped per assignment",
    ),
    source="[hf:HuggingFaceTB/SmolLM-360M; hf]",
)
