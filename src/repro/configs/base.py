"""Config registry: one module per assigned architecture.

Each ``repro/configs/<arch>.py`` exports ``SPEC: ArchSpec`` holding the
exact published configuration, a reduced smoke configuration, and the
architecture's shape set.  ``get_spec('mixtral-8x7b')`` resolves ids.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional

ARCH_IDS = [
    "mixtral-8x7b",
    "mixtral-8x22b",
    "command-r-35b",
    "smollm-360m",
    "tinyllama-1.1b",
    "gat-cora",
    "nequip",
    "gatedgcn",
    "gcn-cora",
    "bst",
    # the paper's own workload, exposed as a selectable arch
    "louvain",
]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                  # lm | gnn | recsys | graph
    config: Any                  # full published config
    smoke: Any                   # reduced config for CPU smoke tests
    shapes: dict                 # shape name -> dict of shape params
    skip_shapes: dict = dataclasses.field(default_factory=dict)
    source: str = ""             # [citation; verification tier]
    notes: str = ""


# ---- canonical shape sets (assignment block) ------------------------------

LM_SHAPES = dict(
    train_4k=dict(kind="train", seq_len=4096, global_batch=256),
    prefill_32k=dict(kind="prefill", seq_len=32768, global_batch=32),
    decode_32k=dict(kind="decode", seq_len=32768, global_batch=128),
    long_500k=dict(kind="decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = dict(
    full_graph_sm=dict(kind="full", n_nodes=2708, n_edges=10556, d_feat=1433,
                       n_classes=7),
    minibatch_lg=dict(kind="sampled", n_nodes=232965, n_edges=114_615_892,
                      batch_nodes=1024, fanout=(15, 10), d_feat=602,
                      n_classes=41),
    ogb_products=dict(kind="full", n_nodes=2_449_029, n_edges=61_859_140,
                      d_feat=100, n_classes=47),
    molecule=dict(kind="batched", n_nodes=30, n_edges=64, batch=128,
                  d_feat=16, n_classes=1),
)

RECSYS_SHAPES = dict(
    train_batch=dict(kind="train", batch=65536),
    serve_p99=dict(kind="serve", batch=512),
    serve_bulk=dict(kind="serve", batch=262144),
    retrieval_cand=dict(kind="retrieval", batch=1, n_candidates=1_000_000),
)

# paper Table 1-scale synthetic graphs for the paper's own workload
GRAPH_SHAPES = dict(
    web_uk2002=dict(kind="community", n_nodes=18_520_486, n_edges=567_000_000),
    road_europe=dict(kind="community", n_nodes=50_912_018, n_edges=108_109_320),
    soc_orkut=dict(kind="community", n_nodes=3_072_441, n_edges=234_370_166),
    kmer_v1r=dict(kind="community", n_nodes=214_005_017, n_edges=465_410_904),
)


def get_spec(arch_id: str) -> ArchSpec:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_")
    )
    return mod.SPEC


def all_cells(include_graph: bool = False):
    """Every (arch, shape) pair in the assignment matrix (+skips marked)."""
    cells = []
    for a in ARCH_IDS:
        if a == "louvain" and not include_graph:
            continue
        spec = get_spec(a)
        for s in spec.shapes:
            cells.append((a, s, spec.skip_shapes.get(s)))
    return cells
