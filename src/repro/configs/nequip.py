"""NequIP [arXiv:2101.03164; paper] — 5 layers, 32 hidden, l_max=2,
8 RBFs, cutoff 5.0 A, E(3) tensor-product messages."""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import NequIPConfig

CONFIG = NequIPConfig(name="nequip", n_layers=5, d_hidden=32, l_max=2,
                      n_rbf=8, cutoff=5.0)
SMOKE = NequIPConfig(name="nequip-smoke", n_layers=2, d_hidden=8, l_max=2,
                     n_rbf=4, cutoff=5.0)

SPEC = ArchSpec(
    arch_id="nequip",
    family="gnn",
    config=CONFIG,
    smoke=SMOKE,
    shapes=GNN_SHAPES,
    source="[arXiv:2101.03164; paper]",
    notes="positions/species are the model inputs; non-molecular shapes get "
          "synthetic 3D embeddings of the graph (input_specs provides them)",
)
