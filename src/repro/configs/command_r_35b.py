"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified] — dense
40L 8192d 64H (GQA kv=8), d_ff=22528, vocab 256000, no biases."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="command-r-35b",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22528, vocab=256000,
    sliding_window=None, rope_theta=8e6,
    compute_dtype=jnp.bfloat16, remat=True,
)

SMOKE = LMConfig(
    name="command-r-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=160, vocab=256,
    compute_dtype=jnp.float32, remat=False, attn_chunk=16,
)

SPEC = ArchSpec(
    arch_id="command-r-35b",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    shapes=LM_SHAPES,
    skip_shapes=dict(
        long_500k="pure full attention: a 512k dense cache/attention row is "
                  "quadratic; skipped per assignment (DESIGN.md §5)",
    ),
    source="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
)
