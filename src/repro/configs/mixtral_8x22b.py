"""Mixtral 8x22B [arXiv:2401.04088; hf] — 56L 6144d 48H (GQA kv=8)
d_ff=16384, vocab 32768, MoE 8 experts top-2, sliding-window attention."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=32768,
    n_experts=8, top_k=2,
    sliding_window=4096, rope_theta=1e6,
    compute_dtype=jnp.bfloat16, remat=True,
)

SMOKE = LMConfig(
    name="mixtral-8x22b-smoke",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
    d_ff=192, vocab=128, n_experts=4, top_k=2, sliding_window=32,
    compute_dtype=jnp.float32, remat=False, attn_chunk=16,
)

SPEC = ArchSpec(
    arch_id="mixtral-8x22b",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    shapes=LM_SHAPES,
    skip_shapes={},
    source="[arXiv:2401.04088; hf]",
)
