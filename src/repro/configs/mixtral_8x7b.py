"""Mixtral 8x7B [arXiv:2401.04088; hf] — 32L 4096d 32H (GQA kv=8)
d_ff=14336, vocab 32000, MoE 8 experts top-2, sliding-window attention."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=32000,
    n_experts=8, top_k=2,
    sliding_window=4096, rope_theta=1e6,
    compute_dtype=jnp.bfloat16, remat=True, remat_policy="dots",
)

SMOKE = LMConfig(
    name="mixtral-8x7b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=128, n_experts=4, top_k=2, sliding_window=32,
    compute_dtype=jnp.float32, remat=False, attn_chunk=16,
)

SPEC = ArchSpec(
    arch_id="mixtral-8x7b",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    shapes=LM_SHAPES,
    # SWA => sub-quadratic; long_500k runs with the rolling-window cache
    skip_shapes={},
    source="[arXiv:2401.04088; hf]",
)
