"""BST [arXiv:1905.06874; paper] — Behavior Sequence Transformer:
embed_dim 32, seq_len 20, 1 block, 8 heads, MLP 1024-512-256.
Table sizes follow the paper's Taobao-scale setting (huge sparse tables)."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import BSTConfig

CONFIG = BSTConfig(
    name="bst",
    item_vocab=4_000_000,
    user_vocab=2_000_000,
    n_user_fields=8,
    user_field_vocab=100_000,
    embed_dim=32,
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    d_ff=64,
    mlp=(1024, 512, 256),
)

SMOKE = BSTConfig(
    name="bst-smoke",
    item_vocab=1000, user_vocab=500, n_user_fields=4, user_field_vocab=100,
    embed_dim=16, seq_len=8, n_blocks=1, n_heads=4, d_ff=32, mlp=(64, 32),
)

SPEC = ArchSpec(
    arch_id="bst",
    family="recsys",
    config=CONFIG,
    smoke=SMOKE,
    shapes=RECSYS_SHAPES,
    source="[arXiv:1905.06874; paper]",
)
