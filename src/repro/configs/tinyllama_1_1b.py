"""TinyLlama 1.1B [arXiv:2401.02385; hf] — llama2-arch small:
22L 2048d 32H (GQA kv=4), d_ff=5632, vocab 32000."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="tinyllama-1.1b",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_head=64,
    d_ff=5632, vocab=32000,
    sliding_window=None, rope_theta=1e4,
    compute_dtype=jnp.bfloat16, remat=True,
)

SMOKE = LMConfig(
    name="tinyllama-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=176, vocab=128,
    compute_dtype=jnp.float32, remat=False, attn_chunk=16,
)

SPEC = ArchSpec(
    arch_id="tinyllama-1.1b",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    shapes=LM_SHAPES,
    skip_shapes=dict(
        long_500k="pure full attention (quadratic); skipped per assignment",
    ),
    source="[arXiv:2401.02385; hf]",
)
