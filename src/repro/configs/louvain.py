"""GSP-Louvain — the paper's own workload as a selectable arch.

Shapes mirror paper Table 1 graph scales (SuiteSparse); the dry-run lowers
one full distributed pass (local-move + split + aggregate) over vertex-
aligned edge shards (DESIGN.md §4)."""
from repro.configs.base import ArchSpec, GRAPH_SHAPES
from repro.core.louvain import LouvainConfig

CONFIG = LouvainConfig(split="sp-pj")
SMOKE = LouvainConfig(split="sp-pj", max_passes=3, max_iters=8)

SPEC = ArchSpec(
    arch_id="louvain",
    family="graph",
    config=CONFIG,
    smoke=SMOKE,
    shapes=GRAPH_SHAPES,
    source="[this paper; Table 1 scales]",
)
