"""GAT (cora config) [arXiv:1710.10903; paper] — 2 layers, 8 hidden,
8 attention heads."""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import GATConfig

CONFIG = GATConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8)
SMOKE = GATConfig(name="gat-smoke", n_layers=2, d_in=12, d_hidden=4,
                  n_heads=2, n_classes=3)

SPEC = ArchSpec(
    arch_id="gat-cora",
    family="gnn",
    config=CONFIG,
    smoke=SMOKE,
    shapes=GNN_SHAPES,
    source="[arXiv:1710.10903; paper]",
)
