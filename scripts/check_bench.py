#!/usr/bin/env python
"""Service-bench regression gate (``scripts/ci.sh bench``).

Runs ``benchmarks/bench_service.py`` (which itself enforces the hard
acceptance bars: engine/async >= 3.5x vs the fused sequential baseline,
update batch >= 3x — plain edge deltas AND the vertex-churn update mix —
fused sortscan backend >= 1.2x end-to-end, deferred-compaction stream
ingest >= 0.8x immediate, exact
partition parity) plus the kernel-level
paired sweep metric from ``benchmarks/bench_kernels.py``, parses the
CSV/marker output into a metrics snapshot, compares against the committed
snapshot ``benchmarks/BENCH_service.json``, and fails when any
higher-is-better metric regressed more than ``--tolerance`` (default
20%).  The quality-tier markers from bench section 10 face a separate
ABSOLUTE gate (``quality_gate``): max-quality modularity >= standard,
standard within 2% of max-quality, zero internally-disconnected
communities for both contract-bearing tiers.  On success the snapshot
is rewritten with the new numbers — committing it advances the
recorded trajectory.

Only the speedup metrics are gated: they are paired ratios (numerator
and denominator measured adjacent), robust to the shared-CPU noise of
the dev container.  Absolute graphs/s metrics and the telemetry
per-phase shares (``phase_share_queue/engine/host``) are recorded in
the snapshot for trend visibility but NOT gated — a busy host halves
throughput without any code regression (observed while validating this
gate), and a share is a shape, not a speed.  The
GitHub workflow merely lints that the committed snapshot parses (see
.github/workflows/ci.yml).

Usage:
  python scripts/check_bench.py                 # run bench + gate + write
  python scripts/check_bench.py --from-file OUT # gate a saved bench log
  python scripts/check_bench.py --no-write      # gate without advancing
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SNAPSHOT = REPO / "benchmarks" / "BENCH_service.json"

# marker-line metrics: "# <name>,<value>" printed by accept_speedup /
# bench_kernels.bench_fused_sweep
SPEEDUPS = {
    "speedup_batch32": "engine_speedup_batch32",
    "speedup_async_batch32": "async_speedup_batch32",
    "speedup_update_batch32": "update_speedup_batch32",
    "speedup_vchurn_batch32": "vchurn_speedup_batch32",
    "speedup_louvain_fused": "louvain_fused_speedup",
    "speedup_sweep_fused": "kernel_sweep_fused_speedup",
    "speedup_telemetry_on": "telemetry_on_speedup",
    "speedup_stream_deferred": "stream_deferred_speedup",
    "speedup_resilience_on": "resilience_on_speedup",
}
# marker-line metrics recorded in the snapshot but NEVER gated: the
# queue/engine/host phase shares from the instrumented bench run are a
# shape of where time goes (they sum to 1), not a speed — a share shift
# is signal for a human, not a regression.  (The telemetry *speedup* has
# its own hard 0.95x bar inside bench_service.py.)
INFORMATIONAL = {
    "phase_share_queue": "phase_share_queue",
    "phase_share_engine": "phase_share_engine",
    "phase_share_host": "phase_share_host",
    # forced-host 2-device mesh shares cores: overhead ceiling, not a
    # speedup — parity (bit-identical partitions) is asserted in-bench
    "speedup_sharded_2dev": "sharded_2dev_speedup",
    "sharded_parity": "sharded_parity",
    # quality-tier portfolio (bench section 10): modularity and
    # disconnected counts are gated ABSOLUTELY by quality_gate() below
    # (structural relations between tiers, not wall-clock trends); the
    # per-tier latencies are trend data — tier cost ordering is
    # hardware-dependent and the fast tier's product is its contract
    "tier_modularity_fast": "tier_modularity_fast",
    "tier_modularity_standard": "tier_modularity_standard",
    "tier_modularity_maxq": "tier_modularity_maxq",
    "tier_disconnected_fast": "tier_disconnected_fast",
    "tier_disconnected_standard": "tier_disconnected_standard",
    "tier_disconnected_maxq": "tier_disconnected_maxq",
    "tier_latency_ms_fast": "tier_latency_ms_fast",
    "tier_latency_ms_standard": "tier_latency_ms_standard",
    "tier_latency_ms_maxq": "tier_latency_ms_maxq",
}
# CSV rows whose derived field leads with "<x> graphs/s"; recorded in the
# snapshot for trend visibility, NOT gated (absolute wall-clock collapses
# under host contention with no code change)
THROUGHPUTS = {
    "service_engine_batch32": "engine_graphs_per_s",
    "service_update_batch32": "update_batch_graphs_per_s",
    "service_stream_ingest": "stream_events_per_s",
}
GATED = set(SPEEDUPS.values())


def run_bench() -> str:
    env = {**os.environ, "PYTHONPATH":
           f"{REPO / 'src'}:{REPO}:{os.environ.get('PYTHONPATH', '')}"}
    out = []
    for script in ["bench_service.py", "bench_kernels.py"]:
        cmd = [sys.executable, str(REPO / "benchmarks" / script)]
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env)
        sys.stdout.write(proc.stdout)
        sys.stderr.write(proc.stderr)
        if proc.returncode != 0:
            sys.exit(f"{script} failed (exit {proc.returncode}) — "
                     "acceptance bars are enforced by the bench itself")
        out.append(proc.stdout)
    return "\n".join(out)


def parse_metrics(out: str) -> dict:
    metrics = {}
    for line in out.splitlines():
        line = line.strip()
        if line.startswith("# "):
            parts = line[2:].split(",")
            if len(parts) == 2 and parts[0] in SPEEDUPS:
                metrics[SPEEDUPS[parts[0]]] = float(parts[1])
            elif len(parts) == 2 and parts[0] in INFORMATIONAL:
                metrics[INFORMATIONAL[parts[0]]] = float(parts[1])
        else:
            parts = line.split(",")
            if len(parts) >= 3 and parts[0] in THROUGHPUTS:
                derived = parts[2]
                for unit in (" graphs/s", " events/s"):
                    if derived.endswith(unit):
                        metrics[THROUGHPUTS[parts[0]]] = float(
                            derived[:-len(unit)])
    missing = ({*SPEEDUPS.values(), *THROUGHPUTS.values(),
                *INFORMATIONAL.values()} - set(metrics))
    if missing:
        sys.exit(f"bench output missing metrics: {sorted(missing)}")
    return metrics


def check(metrics: dict, baseline: dict, tolerance: float) -> list[str]:
    failures = []
    for name, old in baseline.get("metrics", {}).items():
        new = metrics.get(name)
        if new is None:
            failures.append(f"{name}: present in snapshot, missing now")
            continue
        if name not in GATED:
            print(f"bench-gate {name}: {new:.2f} vs snapshot {old:.2f} "
                  "(informational)")
            continue
        floor = (1.0 - tolerance) * old
        status = "OK" if new >= floor else "REGRESSED"
        print(f"bench-gate {name}: {new:.2f} vs snapshot {old:.2f} "
              f"(floor {floor:.2f}) {status}")
        if new < floor:
            failures.append(
                f"{name} regressed >{tolerance:.0%}: {new:.2f} < "
                f"{floor:.2f} (snapshot {old:.2f})")
    return failures


def quality_gate(metrics: dict) -> list[str]:
    """Portfolio quality axis (bench section 10), gated ABSOLUTELY.

    Unlike the speedup floors these are structural relations between
    deterministic quantities, so they compare against fixed bars rather
    than the snapshot: max-quality's best-of-two selection makes its
    modularity >= standard's by construction, standard must stay within
    2% of max-quality (the refine tier buys a small, bounded margin —
    if standard falls further behind, its pipeline regressed), and both
    contract-bearing tiers must report zero internally-disconnected
    communities (the paper invariant the portfolio sells).
    """
    failures = []
    q_std = metrics["tier_modularity_standard"]
    q_max = metrics["tier_modularity_maxq"]
    if q_max < q_std - 1e-9:
        failures.append(
            f"max-quality modularity {q_max:.4f} < standard {q_std:.4f}"
            " (best-of-two selection broken)")
    if q_std < 0.98 * q_max:
        failures.append(
            f"standard modularity {q_std:.4f} < 98% of max-quality "
            f"{q_max:.4f} (standard pipeline regressed)")
    for name in ("tier_disconnected_standard", "tier_disconnected_maxq"):
        if metrics[name] != 0.0:
            failures.append(
                f"{name} = {metrics[name]:g}, contract promises 0")
    for name, val in sorted(metrics.items()):
        if name.startswith("tier_"):
            print(f"quality-gate {name}: {val:.4f}")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--from-file", type=pathlib.Path, default=None,
                    help="parse a saved bench log instead of running")
    ap.add_argument("--snapshot", type=pathlib.Path, default=SNAPSHOT)
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional regression (default 0.20)")
    ap.add_argument("--no-write", action="store_true",
                    help="gate only; do not rewrite the snapshot")
    args = ap.parse_args(argv)

    out = (args.from_file.read_text() if args.from_file
           else run_bench())
    metrics = parse_metrics(out)

    qfail = quality_gate(metrics)
    if qfail:
        sys.exit("bench quality gate FAILED:\n  " + "\n  ".join(qfail))

    if args.snapshot.exists():
        baseline = json.loads(args.snapshot.read_text())
        failures = check(metrics, baseline, args.tolerance)
        if failures:
            sys.exit("bench regression gate FAILED:\n  "
                     + "\n  ".join(failures))
    else:
        print(f"bench-gate: no snapshot at {args.snapshot}; "
              "starting the trajectory")

    if not args.no_write:
        args.snapshot.write_text(json.dumps(
            {"bench": "benchmarks/bench_service.py",
             "tolerance": args.tolerance,
             "metrics": {k: round(v, 3) for k, v in sorted(
                 metrics.items())}},
            indent=2) + "\n")
        print(f"bench-gate: wrote {args.snapshot}")
    print("bench-gate OK")


if __name__ == "__main__":
    main()
