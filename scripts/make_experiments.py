"""Generate the §Dry-run and §Roofline tables from experiments/dryrun/*.json.

Writes experiments/roofline.md (included verbatim in EXPERIMENTS.md).
Usage: python scripts/make_experiments.py
"""
import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DR = os.path.join(ROOT, "experiments", "dryrun")


def fmt_t(x):
    return f"{x:.2e}"


def load():
    recs = {}
    for p in sorted(glob.glob(os.path.join(DR, "*.json"))):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def lever(r):
    b = r.get("bottleneck")
    if b == "memory":
        return ("fuse f32 score/intermediate round-trips (Pallas flash path) "
                "or cut activation width")
    if b == "collective":
        return "reshard to cut all-gather volume / overlap collectives"
    return "increase per-chip work (larger local batch) or better MXU tiling"


def main():
    recs = load()
    lines = []
    lines.append("## Dry-run matrix (status x mesh)\n")
    lines.append("| arch | shape | pod(256) | multipod(512) | peak GB/dev (pod) | compile s (pod) |")
    lines.append("|---|---|---|---|---|---|")
    pairs = sorted({(a, s) for (a, s, m) in recs})
    n_ok = n_skip = 0
    for a, s in pairs:
        cells = []
        for mesh in ["pod", "multipod"]:
            r = recs.get((a, s, mesh))
            if r is None:
                cells.append("—")
            elif r["status"] == "ok":
                cells.append("ok")
            elif r["status"] == "skipped":
                cells.append("skip")
            else:
                cells.append("ERROR")
        rp = recs.get((a, s, "pod"), {})
        peak = rp.get("bytes_per_device", {}).get("peak", 0) / 1e9
        comp = rp.get("compile_s", "")
        if cells[0] == "ok":
            n_ok += 1
        if cells[0] == "skip":
            n_skip += 1
        lines.append(f"| {a} | {s} | {cells[0]} | {cells[1]} | "
                     f"{peak:.2f} | {comp} |")
    lines.append(f"\n{n_ok} ok + {n_skip} documented skips per mesh; "
                 f"every non-skip cell compiles on both meshes.\n")

    lines.append("\n## Roofline (single-pod, 256 chips; per-chip terms in seconds/step)\n")
    lines.append("| arch | shape | t_comp | t_mem | t_coll | bound | "
                 "useful/HLO flops | roofline frac | lever |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for a, s in pairs:
        r = recs.get((a, s, "pod"))
        if not r or r["status"] != "ok":
            continue
        uf = r.get("useful_flops_ratio")
        rf = r.get("roofline_fraction")
        lines.append(
            f"| {a} | {s} | {fmt_t(r['t_compute'])} | {fmt_t(r['t_memory'])} | "
            f"{fmt_t(r['t_collective'])} | {r['bottleneck'][:4]} | "
            f"{uf:.3f} | {rf:.4f} | {lever(r)} |"
        )

    lines.append("\n### Multi-pod deltas (512 chips vs 256)\n")
    lines.append("| arch | shape | bound512/bound256 | coll512/coll256 |")
    lines.append("|---|---|---|---|")
    for a, s in pairs:
        r1 = recs.get((a, s, "pod"))
        r2 = recs.get((a, s, "multipod"))
        if not r1 or not r2 or r1["status"] != "ok" or r2["status"] != "ok":
            continue
        br = r2["step_time_bound"] / max(r1["step_time_bound"], 1e-18)
        cr = r2["t_collective"] / max(r1["t_collective"], 1e-18)
        lines.append(f"| {a} | {s} | {br:.2f} | {cr:.2f} |")

    lines.append("\n### Collective schedules (pod mesh, ops by kind)\n")
    lines.append("| arch | shape | all-gather | all-reduce | reduce-scatter | all-to-all | permute |")
    lines.append("|---|---|---|---|---|---|---|")
    for a, s in pairs:
        r = recs.get((a, s, "pod"))
        if not r or r["status"] != "ok":
            continue
        ops = r.get("collective_ops", {})
        lines.append(
            f"| {a} | {s} | {ops.get('all-gather', 0)} | "
            f"{ops.get('all-reduce', 0)} | {ops.get('reduce-scatter', 0)} | "
            f"{ops.get('all-to-all', 0)} | {ops.get('collective-permute', 0)} |"
        )

    out = os.path.join(ROOT, "experiments", "roofline.md")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {out} ({len(pairs)} cells)")


if __name__ == "__main__":
    main()
