#!/usr/bin/env python
"""Fit the dense-vs-sortscan crossover density from measurements.

``buckets.choose_scan`` decides, per bucket, whether the service engine
runs the dense [nv, nv] community-matrix sweep or the sortscan.  The
crossover used to be the CPU-tuned constant 0.02; this script measures it
on the **current backend**: for a grid of (nv, m_cap) shapes in the
mid-size band where the choice is live (dense_small_nv < nv <=
dense_max_nv), it times ``louvain_impl`` under both scans on synthetic
graphs of matching density and records the density at which the dense
sweep stops winning.  The fitted threshold is the geometric midpoint
between the densest sort-winning and sparsest dense-winning shapes,
pooled over all nv rungs.

Output: ``src/repro/service/dense_scan_calib.json``, keyed by jax backend
(a CPU calibration never misleads a TPU deployment);
:func:`repro.service.buckets.calibrated_min_density` picks it up at
import time.  Commit the file to advance the recorded calibration.

Usage:
  PYTHONPATH=src python scripts/calibrate_dense_scan.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.core import DetectOptions, LouvainConfig, louvain  # noqa: E402
from repro.graph import sbm_graph  # noqa: E402
from repro.graph.container import repad  # noqa: E402
from repro.service.buckets import _CALIB_FILE  # noqa: E402

CFG = LouvainConfig()


def _bench(fn, repeats=3):
    fn()  # compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def measure(nv_rungs, densities, repeats):
    """Times (dense, sort) per shape; returns measurement rows."""
    rows = []
    for n_cap in nv_rungs:
        nv = n_cap + 1
        for dens in densities:
            m_cap = int(dens * nv * nv)
            # synthetic graph at ~60% fill of the bucket's edge capacity
            target_edges = max(int(0.6 * m_cap) // 2, n_cap)
            p = min(target_edges / (n_cap * (n_cap - 1) / 2), 0.9)
            g = sbm_graph(n_nodes=n_cap, n_blocks=max(n_cap // 32, 2),
                          p_in=min(4 * p, 0.9), p_out=p / 4, seed=0)[0]
            if int(g.num_edges()) > m_cap:
                continue
            g = repad(g, n_cap, m_cap)
            t_dense = _bench(
                lambda: louvain(g, options=DetectOptions(
                    louvain=CFG, scan="dense"))[0], repeats)
            t_sort = _bench(lambda: louvain(g, options=DetectOptions(
                louvain=CFG, scan="sort"))[0], repeats)
            rows.append(dict(n_cap=n_cap, m_cap=m_cap,
                             density=round(m_cap / nv / nv, 5),
                             t_dense_ms=round(t_dense * 1e3, 2),
                             t_sort_ms=round(t_sort * 1e3, 2),
                             dense_wins=t_dense < t_sort))
            print(f"  nv={nv:5d} m_cap={m_cap:6d} density={dens:.4f}  "
                  f"dense {t_dense * 1e3:8.1f} ms  sort {t_sort * 1e3:8.1f} "
                  f"ms  -> {'dense' if t_dense < t_sort else 'sort'}")
    return rows


def fit_threshold(rows, fallback=0.02) -> float:
    """Geometric midpoint between the sort-winning and dense-winning
    density bands (pooled over nv rungs; ties resolved toward sort so the
    engine never densifies a shape that measured slower)."""
    sort_d = [r["density"] for r in rows if not r["dense_wins"]]
    dense_d = [r["density"] for r in rows if r["dense_wins"]]
    if not sort_d:   # dense wins everywhere measured: lowest measured band
        return min(dense_d) if dense_d else fallback
    if not dense_d:  # sort wins everywhere: threshold above measured band
        return max(sort_d) * 2.0
    near = [d for d in sort_d if d < max(dense_d) * 4]
    if not near:     # bands don't overlap in a fittable way: split medians
        return float(np.sqrt(np.median(sort_d) * np.median(dense_d)))
    lo = max(near)
    hi = min(dense_d)
    if hi <= lo:     # interleaved bands: split at the crossing point
        return float(np.sqrt(np.median(sort_d) * np.median(dense_d)))
    return float(np.sqrt(lo * hi))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer shapes / repeats (CI smoke)")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="output file (default: the committed calibration; "
                    "--quick defaults to a scratch file instead so a "
                    "2-shape smoke can never clobber the full fit)")
    args = ap.parse_args(argv)

    if args.quick:
        nv_rungs, densities, repeats = [256], [0.008, 0.03], 2
        if args.out is None:
            args.out = pathlib.Path("dense_scan_calib.quick.json")
    else:
        nv_rungs = [192, 256, 512, 1024]
        densities = [0.004, 0.008, 0.016, 0.031, 0.062, 0.125]
        repeats = 3
    if args.out is None:
        args.out = _CALIB_FILE

    backend = jax.default_backend()
    print(f"calibrating dense/sort crossover on backend={backend}")
    rows = measure(nv_rungs, densities, repeats)
    thr = fit_threshold(rows)
    print(f"fitted dense_min_density = {thr:.4f}")

    data = {}
    if args.out.exists():
        try:
            data = json.loads(args.out.read_text())
        except ValueError:
            data = {}
    data[backend] = dict(
        dense_min_density=round(thr, 5),
        fitted_from=f"{len(rows)} shapes",
        measurements=rows,
    )
    args.out.write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
