"""Quick manual smoke of the core pipeline (not a test)."""
import numpy as np
import jax.numpy as jnp

from repro.graph import sbm_graph, bridge_graph, ring_of_cliques
from repro.core import (
    LouvainConfig, louvain, louvain_staged, modularity,
    disconnected_communities, split_labels,
)

def report(name, g, cfg):
    C, stats = louvain(g, cfg)
    q = modularity(g.src, g.dst, g.w, C)
    det = disconnected_communities(g.src, g.dst, g.w, C, g.n_nodes)
    print(
        f"{name:22s} split={cfg.split:7s} Q={float(q):+.4f} "
        f"passes={int(stats['passes'])} comms={int(stats['n_communities'])} "
        f"disc={int(det['n_disconnected'])}/{int(det['n_communities'])}"
    )
    return C, q, det

if __name__ == "__main__":
    g, labels = sbm_graph(n_nodes=200, n_blocks=5, p_in=0.4, p_out=0.01, seed=0)
    gb, bridge = bridge_graph()
    gr = ring_of_cliques(8, 6)

    for name, gg in [("sbm", g), ("bridge", gb), ("ring", gr)]:
        for split in ["none", "sp-pj", "sp-lp", "sl-pj"]:
            report(name, gg, LouvainConfig(split=split))

    # networkx cross-check on sbm
    import networkx as nx
    nxg = g.to_networkx()
    C, stats = louvain(g, LouvainConfig())
    part = {}
    Cn = np.asarray(C)[: int(g.n_nodes)]
    for v, c in enumerate(Cn):
        part.setdefault(int(c), set()).add(v)
    q_nx = nx.algorithms.community.modularity(nxg, list(part.values()))
    print("networkx modularity of our partition:", q_nx)
    comms_nx = nx.algorithms.community.louvain_communities(nxg, seed=0)
    print("networkx louvain Q:", nx.algorithms.community.modularity(nxg, comms_nx))
    # connectivity of every community
    bad = [c for c, vs in part.items() if not nx.is_connected(nxg.subgraph(vs))]
    print("disconnected (nx check):", bad)
