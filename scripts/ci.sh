#!/usr/bin/env bash
# Pre-merge check: tier-1 suite + service smoke.
#
#   scripts/ci.sh
#
# Keep this the documented gate: it is what CHANGES.md entries are
# validated against.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== service smoke =="
python -m repro.launch.serve_communities --smoke

echo "== async service smoke =="
python -m repro.launch.serve_communities --async --smoke
