#!/usr/bin/env bash
# Tiered pre-merge gate.
#
#   scripts/ci.sh [tier1|smoke|bench|all]     (default: all)
#
# Tiers:
#   tier1  — the full pytest suite (ROADMAP's tier-1 verify).  Fast-ish,
#            deterministic; runs on every push/PR (.github/workflows/ci.yml).
#   smoke  — the eight serve_communities end-to-end smokes: the sync pump
#            driver, the async multi-tenant driver, the fully-dynamic
#            churn driver (edge deletions AND vertex additions/removals
#            through the batched warm path, with the vertex round-trip /
#            capacity-reclaim asserts), and the open-loop replay driver
#            (telemetry attached; scrapes the live Prometheus exporter
#            mid-run and asserts the body parses with per-tenant served
#            counters, per-phase latency histograms and compile hit/miss
#            counters), and the temporal-tracking stream driver (planted
#            merge/split/death/birth lifecycle script + removal-heavy
#            event stream with deferred compaction through the windowed
#            snapshot path), and the sharded driver (single-graph
#            detection over a 2-device forced-host mesh: bit-identical
#            parity + zero-disconnected asserted, halo-exchange counters
#            scraped from the live Prometheus exporter), and the chaos
#            driver (deterministic fault injection with retries, a
#            circuit breaker and degraded fallbacks vs a fault-free
#            reference run: goodput floor, bit-identical non-degraded
#            results, breaker recovery and a kill-and-restore automatic
#            checkpoint round trip), and the tiers driver (three tenants
#            pinned to the fast/standard/max-quality portfolio tiers
#            over the same graphs: per-tier QualityContract asserts,
#            max-quality modularity >= standard, deadline auto-routing,
#            tier-labeled counters scraped from the live Prometheus
#            exporter).  Also in the GitHub workflow.
#   bench  — acceptance benchmarks + regression check: scripts/check_bench.py
#            runs benchmarks/bench_service.py + bench_kernels.py, enforces
#            the speedup bars, writes benchmarks/BENCH_service.json and
#            fails on a >20% regression of any paired-speedup metric vs the
#            committed snapshot (absolute graphs/s is informational).
#            Local-only
#            (shared-CPU runners are too noisy); the workflow only lints
#            that the committed snapshot parses.
#   all    — every tier above.  THIS is the documented pre-merge gate: it
#            is what CHANGES.md entries are validated against.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier="${1:-all}"

run_tier1() {
  echo "== tier-1: pytest =="
  python -m pytest -x -q
}

run_smoke() {
  echo "== service smoke =="
  python -m repro.launch.serve_communities --smoke
  echo "== async service smoke =="
  python -m repro.launch.serve_communities --async --smoke
  echo "== churn (dynamic deletions + vertex churn) smoke =="
  python -m repro.launch.serve_communities --churn --smoke
  echo "== replay (open-loop load + live exporter scrape) smoke =="
  python -m repro.launch.serve_communities --replay --smoke
  echo "== stream (temporal tracking + deferred compaction) smoke =="
  python -m repro.launch.serve_communities --stream --smoke
  echo "== sharded (2-device mesh parity + halo telemetry) smoke =="
  python -m repro.launch.serve_communities --sharded --smoke
  echo "== chaos (fault injection + retry/degrade + kill-and-restore) smoke =="
  python -m repro.launch.serve_communities --chaos --smoke
  echo "== tiers (SLO-tiered algorithm portfolio) smoke =="
  python -m repro.launch.serve_communities --tiers --smoke
}

run_bench() {
  echo "== bench: acceptance + regression check =="
  python scripts/check_bench.py
}

case "$tier" in
  tier1) run_tier1 ;;
  smoke) run_smoke ;;
  bench) run_bench ;;
  all)   run_tier1; run_smoke; run_bench ;;
  *)
    echo "usage: scripts/ci.sh [tier1|smoke|bench|all]" >&2
    exit 2
    ;;
esac
