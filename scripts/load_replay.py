#!/usr/bin/env python
"""Open-loop load replay against the community service: one rate, or a
rate sweep that locates the saturation knee.

Wraps :mod:`repro.service.replay`.  Traffic is heavy-tailed in graph
size (Pareto, clipped to the bucket ladder), Zipf-skewed across tenants,
and mixes warm edge updates into the detect stream.  Arrivals are
Poisson at the configured rate and do NOT slow down when the service
falls behind — overflow is rejected (counted), which is what makes the
knee visible.

Single rate:
  PYTHONPATH=src python scripts/load_replay.py --rate 80 --duration 5

Rate sweep (knee detection):
  PYTHONPATH=src python scripts/load_replay.py --sweep 20,40,80,160,320

Write the full per-rate reports (phase breakdowns included) to JSON:
  PYTHONPATH=src python scripts/load_replay.py --sweep 25,50,100 \
      --json replay_sweep.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.core import DetectOptions, LouvainConfig         # noqa: E402
from repro.service.admission import ServiceConfig           # noqa: E402
from repro.service.replay import (                          # noqa: E402
    ReplayConfig, run_replay, sweep_rates,
)


def _fmt_ms(v):
    return "   n/a" if v is None else f"{v:6.1f}"


def print_report(rep: dict):
    print(f"rate {rep['rate']:7.1f}/s  offered {rep['offered']:5d}  "
          f"served {rep['served']:5d}  rejected {rep['rejected']:4d}  "
          f"goodput {rep['goodput']:.2f}")
    print(f"  latency p50 {_fmt_ms(rep['p50_ms'])} ms   "
          f"p99 {_fmt_ms(rep['p99_ms'])} ms   "
          f"({rep['late_arrivals']} late arrivals)")
    bd = rep.get("phase_breakdown")
    if bd:
        print("  breakdown  " + "  ".join(
            f"{k} {v * 100:5.1f}%" for k, v in sorted(bd.items())))
    for name, ph in rep.get("phases", {}).items():
        print(f"    {name:<16} ({ph['group']:<6}) "
              f"p50 {ph['p50_ms']:9.3f} ms  p99 {ph['p99_ms']:9.3f} ms  "
              f"n={ph['count']}")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="open-loop load replay / saturation-knee finder")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="offered arrival rate (req/s)")
    ap.add_argument("--sweep", type=str, default=None,
                    help="comma-separated rate ladder; overrides --rate "
                         "and reports the saturation knee")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="arrival window per rate (seconds)")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--tenant-skew", type=float, default=1.5,
                    help="Zipf exponent over tenants (0 = uniform)")
    ap.add_argument("--update-frac", type=float, default=0.3)
    ap.add_argument("--pool", type=int, default=24,
                    help="distinct graphs cycled through")
    ap.add_argument("--n-min", type=int, default=12)
    ap.add_argument("--n-max", type=int, default=48)
    ap.add_argument("--size-alpha", type=float, default=1.5,
                    help="Pareto shape for graph sizes (smaller = heavier "
                         "tail)")
    ap.add_argument("--no-warm", action="store_true",
                    help="skip the pre-compile/seed phase (latencies will "
                         "include XLA compiles)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--max-delay-ms", type=float, default=25.0)
    ap.add_argument("--max-pending", type=int, default=12,
                    help="per-tenant queue bound")
    ap.add_argument("--knee-goodput", type=float, default=0.9,
                    help="goodput below this marks the knee")
    ap.add_argument("--knee-p99-factor", type=float, default=5.0,
                    help="p99 blowup vs the lowest rate that marks the "
                         "knee")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None,
                    help="write the full report(s) to this JSON file")
    args = ap.parse_args(argv)

    base = ReplayConfig(
        rate=args.rate, duration_s=args.duration, n_tenants=args.tenants,
        tenant_skew=args.tenant_skew, update_frac=args.update_frac,
        pool_size=args.pool, n_min=args.n_min, n_max=args.n_max,
        size_alpha=args.size_alpha, seed=args.seed, warm=not args.no_warm)
    config = ServiceConfig(
        detect=DetectOptions(louvain=LouvainConfig()), batch_size=args.batch,
        max_delay_s=args.max_delay_ms / 1e3,
        max_pending_per_tenant=args.max_pending,
        telemetry_enabled=True)

    if args.sweep:
        rates = [float(r) for r in args.sweep.split(",")]
        out = sweep_rates(rates, base, config,
                          knee_goodput=args.knee_goodput,
                          knee_p99_factor=args.knee_p99_factor)
        for rep in out["rates"]:
            print_report(rep)
        knee = out["knee_rate"]
        print("saturation knee: "
              + (f"{knee:.1f} req/s" if knee is not None
                 else f"not reached up to {max(rates):.1f} req/s"))
    else:
        out = run_replay(base, config)
        print_report(out)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, allow_nan=False)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
